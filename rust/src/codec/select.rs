//! Per-tensor codec selection: trial-compress the menu, track the gap
//! to the Shannon bound.
//!
//! The `.df11` container has tagged a codec id per block since v2, but
//! `compress` always applied one global codec. This module closes that
//! gap (ROADMAP item 3): a [`CodecSelector`] trial-compresses each
//! tensor against the full menu — raw, DF11, rANS, split-stream —
//! under a [`SelectionPolicy`] and emits a [`SelectionReport`]
//! recording, per tensor, the winning codec, the achieved bits/weight,
//! and the measured component Shannon bound from
//! [`crate::entropy::ComponentHistograms`]. The report is both the
//! CLI's `--codec auto` output and the `BENCH_fig1.json` artifact
//! body, so "how far from optimal" is a tracked number instead of a
//! bench printout.
//!
//! Because `auto` picks the per-tensor minimum over the same menu any
//! fixed codec draws from, an auto container can never exceed the best
//! single global codec on the same model — the acceptance property
//! pinned by `selection_beats_every_global_codec` below.

use crate::bf16::Bf16;
use crate::codec::{all_codecs, codec_by_name, Codec, CodecId, CompressedTensor, DecodeOpts};
use crate::entropy::ComponentHistograms;
use crate::error::{Error, Result};

use crate::bench_harness::json::Json;

/// How the selector picks a codec for each tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectionPolicy {
    /// Smallest serialized payload wins, per tensor.
    Auto,
    /// Every tensor uses one fixed codec (the legacy `--codec NAME`
    /// behaviour, expressed as a degenerate selection).
    Fixed(CodecId),
    /// Smallest payload wins, but only if it saves at least
    /// `min_percent` of the raw BF16 bytes — otherwise the tensor is
    /// stored raw. Guards against paying entropy-coding decode cost
    /// for tensors that barely compress (e.g. near-uniform bits).
    MinGain {
        /// Required saving vs raw, in percent of the original bytes.
        min_percent: f64,
    },
}

impl SelectionPolicy {
    /// Parse a CLI spec: `auto`, a fixed codec name (`df11`, `rans`,
    /// `raw`, `split`), or `min-gain[:PERCENT]` (default 5%).
    pub fn parse(spec: &str) -> Result<SelectionPolicy> {
        if spec == "auto" {
            return Ok(SelectionPolicy::Auto);
        }
        if let Some(rest) = spec.strip_prefix("min-gain") {
            let min_percent = match rest.strip_prefix(':') {
                None if rest.is_empty() => 5.0,
                Some(p) => p.parse::<f64>().map_err(|_| {
                    Error::InvalidArgument(format!("bad min-gain threshold {p:?}"))
                })?,
                _ => {
                    return Err(Error::InvalidArgument(format!(
                        "unknown codec policy {spec:?}"
                    )))
                }
            };
            if !(0.0..=100.0).contains(&min_percent) {
                return Err(Error::InvalidArgument(format!(
                    "min-gain threshold {min_percent} outside 0..=100"
                )));
            }
            return Ok(SelectionPolicy::MinGain { min_percent });
        }
        let codec = codec_by_name(spec, DecodeOpts::default())?;
        Ok(SelectionPolicy::Fixed(codec.id()))
    }

    /// Report label.
    pub fn label(&self) -> String {
        match self {
            SelectionPolicy::Auto => "auto".to_string(),
            SelectionPolicy::Fixed(id) => id.label().to_string(),
            SelectionPolicy::MinGain { min_percent } => format!("min-gain:{min_percent}"),
        }
    }
}

/// One trial: what a codec would cost for a tensor.
#[derive(Clone, Copy, Debug)]
pub struct CandidateTrial {
    /// The codec tried.
    pub codec: CodecId,
    /// Its serialized payload bytes.
    pub compressed_bytes: u64,
}

impl CandidateTrial {
    /// Achieved bits per weight for `num_elements` weights.
    pub fn bits_per_weight(&self, num_elements: u64) -> f64 {
        self.compressed_bytes as f64 * 8.0 / num_elements.max(1) as f64
    }
}

/// The selection record for one tensor.
#[derive(Clone, Debug)]
pub struct TensorSelection {
    /// Group the tensor belongs to.
    pub group: String,
    /// Tensor name.
    pub name: String,
    /// Element count.
    pub num_elements: u64,
    /// The winning codec.
    pub codec: CodecId,
    /// Original BF16 bytes.
    pub original_bytes: u64,
    /// Winning payload bytes.
    pub compressed_bytes: u64,
    /// Measured component Shannon bound (H(sign)+H(exp)+H(mantissa)).
    pub optimal_bits_per_weight: f64,
    /// Every codec tried, in menu order.
    pub candidates: Vec<CandidateTrial>,
}

impl TensorSelection {
    /// Achieved bits per weight under the winning codec.
    pub fn achieved_bits_per_weight(&self) -> f64 {
        self.compressed_bytes as f64 * 8.0 / self.num_elements.max(1) as f64
    }

    /// Gap to the Shannon bound, bits per weight (achieved − optimal).
    pub fn gap_bits(&self) -> f64 {
        self.achieved_bits_per_weight() - self.optimal_bits_per_weight
    }
}

/// The selection report for a whole model: per-tensor winners plus the
/// aggregate achieved-vs-optimal accounting.
#[derive(Clone, Debug, Default)]
pub struct SelectionReport {
    /// Policy label the selection ran under.
    pub policy: String,
    /// Per-tensor records, in compression order.
    pub tensors: Vec<TensorSelection>,
}

impl SelectionReport {
    /// Total original BF16 bytes.
    pub fn total_original_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.original_bytes).sum()
    }

    /// Total winning payload bytes.
    pub fn total_compressed_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.compressed_bytes).sum()
    }

    /// Total elements.
    pub fn total_elements(&self) -> u64 {
        self.tensors.iter().map(|t| t.num_elements).sum()
    }

    /// Aggregate achieved bits per weight.
    pub fn achieved_bits_per_weight(&self) -> f64 {
        self.total_compressed_bytes() as f64 * 8.0 / self.total_elements().max(1) as f64
    }

    /// Element-weighted aggregate Shannon bound.
    pub fn optimal_bits_per_weight(&self) -> f64 {
        let n = self.total_elements().max(1) as f64;
        self.tensors
            .iter()
            .map(|t| t.optimal_bits_per_weight * t.num_elements as f64)
            .sum::<f64>()
            / n
    }

    /// Aggregate gap to the Shannon bound, bits per weight.
    pub fn aggregate_gap_bits(&self) -> f64 {
        self.achieved_bits_per_weight() - self.optimal_bits_per_weight()
    }

    /// Compression ratio (compressed / original, percent).
    pub fn ratio_percent(&self) -> f64 {
        self.total_compressed_bytes() as f64 * 100.0 / self.total_original_bytes().max(1) as f64
    }

    /// Total bytes the model would cost under each *single* global
    /// codec (summing that codec's trial across all tensors), in menu
    /// order. Only meaningful when every tensor trialed the full menu.
    pub fn global_codec_totals(&self) -> Vec<(CodecId, u64)> {
        let mut totals: Vec<(CodecId, u64)> = Vec::new();
        for t in &self.tensors {
            for c in &t.candidates {
                match totals.iter_mut().find(|(id, _)| *id == c.codec) {
                    Some((_, sum)) => *sum += c.compressed_bytes,
                    None => totals.push((c.codec, c.compressed_bytes)),
                }
            }
        }
        totals
    }

    /// The best single global codec and its total bytes.
    pub fn best_global_codec(&self) -> Option<(CodecId, u64)> {
        self.global_codec_totals()
            .into_iter()
            .min_by_key(|&(_, bytes)| bytes)
    }

    /// How many tensors each codec won, in menu order.
    pub fn wins(&self) -> Vec<(CodecId, usize)> {
        let mut wins: Vec<(CodecId, usize)> = Vec::new();
        for t in &self.tensors {
            match wins.iter_mut().find(|(id, _)| *id == t.codec) {
                Some((_, n)) => *n += 1,
                None => wins.push((t.codec, 1)),
            }
        }
        wins
    }

    /// The report as a JSON value — the `BENCH_fig1.json` body: one
    /// record per tensor (winner, achieved vs optimal bits, gap) plus
    /// the aggregate gap.
    pub fn to_json(&self) -> Json {
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|t| {
                let candidates: Vec<Json> = t
                    .candidates
                    .iter()
                    .map(|c| {
                        Json::obj()
                            .field("codec", Json::str(c.codec.label()))
                            .field("compressed_bytes", Json::int(c.compressed_bytes))
                            .field(
                                "bits_per_weight",
                                Json::num(c.bits_per_weight(t.num_elements)),
                            )
                    })
                    .collect();
                Json::obj()
                    .field("group", Json::str(&t.group))
                    .field("name", Json::str(&t.name))
                    .field("num_elements", Json::int(t.num_elements))
                    .field("codec", Json::str(t.codec.label()))
                    .field("compressed_bytes", Json::int(t.compressed_bytes))
                    .field(
                        "achieved_bits_per_weight",
                        Json::num(t.achieved_bits_per_weight()),
                    )
                    .field(
                        "optimal_bits_per_weight",
                        Json::num(t.optimal_bits_per_weight),
                    )
                    .field("gap_bits", Json::num(t.gap_bits()))
                    .field("candidates", Json::Array(candidates))
            })
            .collect();
        Json::obj()
            .field("policy", Json::str(&self.policy))
            .field("tensors", Json::Array(tensors))
            .field(
                "achieved_bits_per_weight",
                Json::num(self.achieved_bits_per_weight()),
            )
            .field(
                "optimal_bits_per_weight",
                Json::num(self.optimal_bits_per_weight()),
            )
            .field("aggregate_gap_bits", Json::num(self.aggregate_gap_bits()))
            .field("ratio_percent", Json::num(self.ratio_percent()))
    }
}

/// Trial-compresses tensors against the codec menu under a policy.
pub struct CodecSelector {
    policy: SelectionPolicy,
}

impl CodecSelector {
    /// A selector under `policy`.
    pub fn new(policy: SelectionPolicy) -> CodecSelector {
        CodecSelector { policy }
    }

    /// The policy this selector runs under.
    pub fn policy(&self) -> SelectionPolicy {
        self.policy
    }

    /// The menu, in trial (and tie-break) order. Real codecs come
    /// before `raw` so an exact byte tie never picks the identity
    /// codec over a compressing one.
    pub fn menu(&self) -> Vec<Box<dyn Codec>> {
        match self.policy {
            // A fixed policy compresses once — no trials to run.
            SelectionPolicy::Fixed(id) => all_codecs()
                .into_iter()
                .filter(|c| c.id() == id)
                .collect(),
            _ => all_codecs(),
        }
    }

    /// Select and compress one tensor: trial the menu, pick per the
    /// policy, and return the winning payload with its record.
    pub fn select_shaped(
        &self,
        group: &str,
        name: &str,
        weights: &[Bf16],
        shape: &[usize],
    ) -> Result<(CompressedTensor, TensorSelection)> {
        let mut hist = ComponentHistograms::new();
        hist.record_weights(weights);
        let optimal = hist.entropy().optimal_bits_per_weight();

        let mut candidates = Vec::new();
        let mut best: Option<(usize, CompressedTensor)> = None;
        for codec in self.menu() {
            let parts = codec.compress_shaped(weights, shape)?;
            let bytes = parts.compressed_bytes();
            candidates.push(CandidateTrial {
                codec: codec.id(),
                compressed_bytes: bytes,
            });
            let better = match &best {
                None => true,
                // Strict `<`: ties keep the earlier menu entry, so the
                // winner is deterministic in menu order.
                Some((bi, _)) => bytes < candidates[*bi].compressed_bytes,
            };
            if better {
                best = Some((candidates.len() - 1, parts));
            }
        }
        let (mut winner_idx, mut winner) =
            best.ok_or_else(|| Error::InvalidArgument("empty codec menu".into()))?;

        if let SelectionPolicy::MinGain { min_percent } = self.policy {
            let original = weights.len() as u64 * 2;
            let saved =
                original.saturating_sub(candidates[winner_idx].compressed_bytes) as f64 * 100.0;
            if candidates[winner_idx].codec != CodecId::RawBf16
                && saved < min_percent * original.max(1) as f64
            {
                // Not worth the decode cost: store raw instead.
                let raw_idx = candidates
                    .iter()
                    .position(|c| c.codec == CodecId::RawBf16)
                    .ok_or_else(|| Error::InvalidArgument("menu has no raw codec".into()))?;
                winner = codec_by_name("raw", DecodeOpts::default())?
                    .compress_shaped(weights, shape)?;
                winner_idx = raw_idx;
            }
        }

        let record = TensorSelection {
            group: group.to_string(),
            name: name.to_string(),
            num_elements: weights.len() as u64,
            codec: candidates[winner_idx].codec,
            original_bytes: weights.len() as u64 * 2,
            compressed_bytes: candidates[winner_idx].compressed_bytes,
            optimal_bits_per_weight: optimal,
            candidates,
        };
        Ok((winner, record))
    }

    /// Select and compress a whole model: `(group, name, shape,
    /// weights)` tuples in order. Returns the payloads (container
    /// push order) and the model-level report.
    pub fn select_model<'w>(
        &self,
        tensors: impl IntoIterator<Item = (&'w str, &'w str, &'w [usize], &'w [Bf16])>,
    ) -> Result<(Vec<CompressedTensor>, SelectionReport)> {
        let mut parts = Vec::new();
        let mut report = SelectionReport {
            policy: self.policy.label(),
            tensors: Vec::new(),
        };
        for (group, name, shape, weights) in tensors {
            let (t, record) = self.select_shaped(group, name, weights, shape)?;
            parts.push(t);
            report.tensors.push(record);
        }
        Ok((parts, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian_weights(n: usize, seed: u64) -> Vec<Bf16> {
        let mut rng = Rng::new(seed);
        let mut xs = vec![0f32; n];
        rng.fill_gaussian_f32(&mut xs, 0.02);
        xs.into_iter().map(Bf16::from_f32).collect()
    }

    /// Weights whose 16-bit patterns are uniform noise: nothing in the
    /// menu can beat storing them raw.
    fn uniform_bits(n: usize, seed: u64) -> Vec<Bf16> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| Bf16::from_bits(rng.next_index(1 << 16) as u16))
            .collect()
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(SelectionPolicy::parse("auto").unwrap(), SelectionPolicy::Auto);
        assert_eq!(
            SelectionPolicy::parse("df11").unwrap(),
            SelectionPolicy::Fixed(CodecId::Df11)
        );
        assert_eq!(
            SelectionPolicy::parse("split").unwrap(),
            SelectionPolicy::Fixed(CodecId::SplitStream)
        );
        assert_eq!(
            SelectionPolicy::parse("min-gain").unwrap(),
            SelectionPolicy::MinGain { min_percent: 5.0 }
        );
        assert_eq!(
            SelectionPolicy::parse("min-gain:12.5").unwrap(),
            SelectionPolicy::MinGain { min_percent: 12.5 }
        );
        assert!(SelectionPolicy::parse("min-gain:200").is_err());
        assert!(SelectionPolicy::parse("zstd").is_err());
    }

    #[test]
    fn auto_picks_the_smallest_candidate() {
        let ws = gaussian_weights(40_000, 1);
        let sel = CodecSelector::new(SelectionPolicy::Auto);
        let (parts, record) = sel.select_shaped("g", "t", &ws, &[ws.len()]).unwrap();
        assert_eq!(parts.codec_id(), record.codec);
        assert_eq!(record.candidates.len(), 4, "full menu trialed");
        let min = record
            .candidates
            .iter()
            .map(|c| c.compressed_bytes)
            .min()
            .unwrap();
        assert_eq!(record.compressed_bytes, min);
        assert_eq!(parts.compressed_bytes(), min);
        // Gaussian weights: the split-stream planes win (1 + H(e) + 7
        // beats DF11's 8 + H(e) + aux).
        assert_eq!(record.codec, CodecId::SplitStream);
        assert!(record.gap_bits() >= 0.0, "cannot beat the Shannon bound");
        assert!(record.gap_bits() < 1.0, "gap {}", record.gap_bits());
    }

    #[test]
    fn fixed_policy_compresses_only_its_codec() {
        let ws = gaussian_weights(2_000, 2);
        let sel = CodecSelector::new(SelectionPolicy::Fixed(CodecId::Rans));
        let (parts, record) = sel.select_shaped("g", "t", &ws, &[ws.len()]).unwrap();
        assert_eq!(parts.codec_id(), CodecId::Rans);
        assert_eq!(record.codec, CodecId::Rans);
        assert_eq!(record.candidates.len(), 1);
    }

    #[test]
    fn min_gain_falls_back_to_raw_on_incompressible_bits() {
        let ws = uniform_bits(8_000, 3);
        let sel = CodecSelector::new(SelectionPolicy::MinGain { min_percent: 5.0 });
        let (parts, record) = sel.select_shaped("g", "t", &ws, &[ws.len()]).unwrap();
        assert_eq!(parts.codec_id(), CodecId::RawBf16);
        assert_eq!(record.codec, CodecId::RawBf16);
        assert_eq!(record.compressed_bytes, ws.len() as u64 * 2);
        // Gaussian weights clear any reasonable threshold.
        let ws = gaussian_weights(40_000, 4);
        let (parts, _) = sel.select_shaped("g", "t", &ws, &[ws.len()]).unwrap();
        assert_ne!(parts.codec_id(), CodecId::RawBf16);
    }

    #[test]
    fn selection_beats_every_global_codec() {
        // The acceptance property: per-tensor minima can never sum to
        // more than the best single global codec.
        let sel = CodecSelector::new(SelectionPolicy::Auto);
        let tensors: Vec<(String, Vec<Bf16>)> = (0..4)
            .map(|i| (format!("t{i}"), gaussian_weights(3_000 + i * 500, i as u64)))
            .collect();
        let shapes: Vec<Vec<usize>> = tensors.iter().map(|(_, w)| vec![w.len()]).collect();
        let (_, report) = sel
            .select_model(
                tensors
                    .iter()
                    .zip(&shapes)
                    .map(|((name, w), shape)| ("g", name.as_str(), &shape[..], &w[..])),
            )
            .unwrap();
        let (best_id, best_total) = report.best_global_codec().unwrap();
        assert!(
            report.total_compressed_bytes() <= best_total,
            "auto {} > best global {} ({})",
            report.total_compressed_bytes(),
            best_total,
            best_id.label()
        );
        assert_eq!(report.tensors.len(), 4);
        assert!(report.aggregate_gap_bits() >= 0.0);
    }

    #[test]
    fn report_json_has_per_tensor_gap_fields() {
        let ws = gaussian_weights(5_000, 6);
        let sel = CodecSelector::new(SelectionPolicy::Auto);
        let (_, report) = sel
            .select_model([("g", "embed.tok", &[ws.len()][..], &ws[..])])
            .unwrap();
        let rendered = report.to_json().render();
        for key in [
            "\"policy\":\"auto\"",
            "\"name\":\"embed.tok\"",
            "achieved_bits_per_weight",
            "optimal_bits_per_weight",
            "aggregate_gap_bits",
            "candidates",
        ] {
            assert!(rendered.contains(key), "missing {key} in {rendered}");
        }
    }
}
