//! KV-cache manager.
//!
//! Figure 5's experiment: with a fixed HBM budget, how many tokens can
//! be decoded before OOM? DF11 frees ~30% of weight memory, which goes
//! to the KV cache, extending generation 5.7–14.9×. This manager tracks
//! per-sequence cache growth against the simulated HBM allocator and
//! also *owns the real buffers* for executable-scale models (the serving
//! engine stores K/V literals per layer here).

use crate::error::{Error, Result};
use crate::gpu_sim::{HbmAllocator, MemoryCategory};
use crate::model::ModelConfig;
use std::collections::HashMap;

/// Per-sequence cache state.
#[derive(Debug)]
struct SeqCache {
    tokens: u64,
    allocs: Vec<crate::gpu_sim::memory::AllocId>,
}

/// KV cache manager over a simulated HBM budget.
#[derive(Debug)]
pub struct KvCacheManager {
    bytes_per_token: u64,
    page_tokens: u64,
    seqs: HashMap<u64, SeqCache>,
}

impl KvCacheManager {
    /// Manager for a model config. `page_tokens` is the allocation
    /// granularity (vLLM-style paging; 16 is the common default).
    pub fn new(config: &ModelConfig, page_tokens: u64) -> Self {
        Self::with_bytes_per_token(config.kv_bytes_per_token(), page_tokens)
    }

    /// Manager with an explicit per-token byte rate. Shard-scoped
    /// engines budget only their resident layer slice, so their rate is
    /// `2 * owned_layers * kv_dim * 2` rather than the full model's.
    pub fn with_bytes_per_token(bytes_per_token: u64, page_tokens: u64) -> Self {
        KvCacheManager {
            bytes_per_token,
            page_tokens: page_tokens.max(1),
            seqs: HashMap::new(),
        }
    }

    /// Bytes per token (all layers, K+V).
    pub fn bytes_per_token(&self) -> u64 {
        self.bytes_per_token
    }

    /// Allocation granularity in tokens (vLLM-style page size).
    pub fn page_tokens(&self) -> u64 {
        self.page_tokens
    }

    /// Bytes of one KV page.
    pub fn bytes_per_page(&self) -> u64 {
        self.page_tokens * self.bytes_per_token
    }

    /// Pages needed to hold `tokens` (at least one — a sequence always
    /// occupies a page). Drives the scheduler's page-granular admission.
    pub fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens).max(1)
    }

    /// Total live tokens across all registered sequences.
    pub fn total_tokens(&self) -> u64 {
        self.seqs.values().map(|s| s.tokens).sum()
    }

    /// Register a new sequence.
    pub fn add_sequence(&mut self, seq_id: u64) -> Result<()> {
        if self.seqs.contains_key(&seq_id) {
            return Err(Error::InvalidArgument(format!(
                "sequence {seq_id} already registered"
            )));
        }
        self.seqs.insert(
            seq_id,
            SeqCache {
                tokens: 0,
                allocs: Vec::new(),
            },
        );
        Ok(())
    }

    /// Extend a sequence by `new_tokens`, allocating pages from `hbm` as
    /// needed. On OOM the sequence is left unchanged and the error
    /// propagates (the scheduler decides whether to evict or reject).
    pub fn extend(&mut self, hbm: &mut HbmAllocator, seq_id: u64, new_tokens: u64) -> Result<()> {
        let bytes_per_page = self.page_tokens * self.bytes_per_token;
        let seq = self
            .seqs
            .get_mut(&seq_id)
            .ok_or_else(|| Error::KvCacheExhausted(format!("unknown sequence {seq_id}")))?;
        let have_pages = seq.allocs.len() as u64;
        let need_pages = (seq.tokens + new_tokens).div_ceil(self.page_tokens);
        let mut new_allocs = Vec::new();
        for _ in have_pages..need_pages {
            match hbm.alloc(MemoryCategory::KvCache, bytes_per_page) {
                Ok(id) => new_allocs.push(id),
                Err(e) => {
                    // Roll back partial page allocations.
                    for id in new_allocs {
                        hbm.free(id).expect("rollback of fresh alloc");
                    }
                    return Err(e);
                }
            }
        }
        seq.allocs.extend(new_allocs);
        seq.tokens += new_tokens;
        Ok(())
    }

    /// Current token count of a sequence.
    pub fn tokens(&self, seq_id: u64) -> u64 {
        self.seqs.get(&seq_id).map(|s| s.tokens).unwrap_or(0)
    }

    /// Pages a sequence currently holds.
    pub fn pages_held(&self, seq_id: u64) -> u64 {
        self.seqs
            .get(&seq_id)
            .map(|s| s.allocs.len() as u64)
            .unwrap_or(0)
    }

    /// Extra pages an `extend(seq_id, new_tokens)` would have to
    /// allocate. Lets a caller check affordability across several
    /// budgets *before* committing any of them (the sharded engine
    /// must extend every shard's budget or none).
    pub fn pages_needed(&self, seq_id: u64, new_tokens: u64) -> u64 {
        let tokens = self.tokens(seq_id);
        (tokens + new_tokens)
            .div_ceil(self.page_tokens)
            .saturating_sub(self.pages_held(seq_id))
    }

    /// Release a sequence and free its pages.
    pub fn release(&mut self, hbm: &mut HbmAllocator, seq_id: u64) -> Result<()> {
        let seq = self
            .seqs
            .remove(&seq_id)
            .ok_or_else(|| Error::InvalidArgument(format!("unknown sequence {seq_id}")))?;
        for id in seq.allocs {
            hbm.free(id)?;
        }
        Ok(())
    }

    /// Total live sequences.
    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Analytic: max tokens decodable (batch of `batch` sequences grown
    /// uniformly) within `budget_bytes` — the Figure 5 curve's OOM point.
    pub fn max_tokens_within(&self, budget_bytes: u64, batch: u64) -> u64 {
        let per_page = self.page_tokens * self.bytes_per_token;
        let pages = budget_bytes / per_page;
        (pages / batch.max(1)) * self.page_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::Device;

    fn small_device(bytes: u64) -> Device {
        Device {
            name: "KV-TEST",
            hbm_bytes: bytes,
            hbm_bw: 1e12,
            sram_per_block: 100 << 10,
            sm_count: 100,
            pcie_bw: 25e9,
            pcie_latency: 1e-5,
            bf16_flops: 1e14,
        }
    }

    #[test]
    fn extend_allocates_pages_lazily() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 16);
        let mut hbm = HbmAllocator::new(small_device(1 << 30));
        mgr.add_sequence(1).unwrap();
        mgr.extend(&mut hbm, 1, 10).unwrap();
        let one_page = 16 * mgr.bytes_per_token();
        assert_eq!(hbm.used(), one_page);
        mgr.extend(&mut hbm, 1, 6).unwrap(); // exactly fills the page
        assert_eq!(hbm.used(), one_page);
        mgr.extend(&mut hbm, 1, 1).unwrap(); // spills into page 2
        assert_eq!(hbm.used(), 2 * one_page);
        assert_eq!(mgr.tokens(1), 17);
    }

    #[test]
    fn oom_rolls_back_cleanly() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 16);
        let page = 16 * mgr.bytes_per_token();
        // Budget: 2.5 pages.
        let mut hbm = HbmAllocator::new(small_device(page * 5 / 2));
        mgr.add_sequence(1).unwrap();
        mgr.extend(&mut hbm, 1, 32).unwrap(); // 2 pages
        let before = hbm.used();
        // Needs 2 more pages; only ~0.5 available.
        let e = mgr.extend(&mut hbm, 1, 32);
        assert!(e.is_err());
        assert_eq!(hbm.used(), before, "partial pages must be rolled back");
        assert_eq!(mgr.tokens(1), 32);
    }

    #[test]
    fn release_frees_everything() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 8);
        let mut hbm = HbmAllocator::new(small_device(1 << 30));
        mgr.add_sequence(7).unwrap();
        mgr.extend(&mut hbm, 7, 100).unwrap();
        assert!(hbm.used() > 0);
        mgr.release(&mut hbm, 7).unwrap();
        assert_eq!(hbm.used(), 0);
        assert_eq!(mgr.num_sequences(), 0);
    }

    #[test]
    fn page_math_helpers() {
        let cfg = ModelConfig::test_tiny();
        let mgr = KvCacheManager::new(&cfg, 16);
        assert_eq!(mgr.page_tokens(), 16);
        assert_eq!(mgr.bytes_per_page(), 16 * mgr.bytes_per_token());
        assert_eq!(mgr.pages_for(0), 1, "a sequence always holds a page");
        assert_eq!(mgr.pages_for(1), 1);
        assert_eq!(mgr.pages_for(16), 1);
        assert_eq!(mgr.pages_for(17), 2);
    }

    #[test]
    fn pages_needed_predicts_extend_cost() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 16);
        let mut hbm = HbmAllocator::new(small_device(1 << 30));
        mgr.add_sequence(1).unwrap();
        // Fresh sequence: the first token claims page 1.
        assert_eq!(mgr.pages_held(1), 0);
        assert_eq!(mgr.pages_needed(1, 1), 1);
        mgr.extend(&mut hbm, 1, 10).unwrap();
        assert_eq!(mgr.pages_held(1), 1);
        // 6 more fit the page; the 7th spills.
        assert_eq!(mgr.pages_needed(1, 6), 0);
        assert_eq!(mgr.pages_needed(1, 7), 1);
        // Unknown sequences hold nothing.
        assert_eq!(mgr.pages_held(9), 0);
    }

    #[test]
    fn scoped_byte_rate_constructor() {
        // A shard owning half the layers charges half the bytes/token.
        let cfg = ModelConfig::test_tiny();
        let full = KvCacheManager::new(&cfg, 16);
        let half = KvCacheManager::with_bytes_per_token(cfg.kv_bytes_per_token() / 2, 16);
        assert_eq!(half.bytes_per_token() * 2, full.bytes_per_token());
        assert_eq!(half.bytes_per_page() * 2, full.bytes_per_page());
    }

    #[test]
    fn total_tokens_tracks_live_sequences() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 8);
        let mut hbm = HbmAllocator::new(small_device(1 << 30));
        mgr.add_sequence(1).unwrap();
        mgr.add_sequence(2).unwrap();
        mgr.extend(&mut hbm, 1, 5).unwrap();
        mgr.extend(&mut hbm, 2, 9).unwrap();
        assert_eq!(mgr.total_tokens(), 14);
        mgr.release(&mut hbm, 1).unwrap();
        assert_eq!(mgr.total_tokens(), 9);
    }

    #[test]
    fn duplicate_sequence_rejected() {
        let cfg = ModelConfig::test_tiny();
        let mut mgr = KvCacheManager::new(&cfg, 8);
        mgr.add_sequence(1).unwrap();
        assert!(mgr.add_sequence(1).is_err());
    }

    #[test]
    fn figure5_shape_df11_allows_more_tokens() {
        // DF11 frees ~30% of weight bytes; the freed memory extends the
        // token budget by (free_df11 / free_bf16)x.
        let cfg = crate::model::zoo::llama31_8b();
        let mgr = KvCacheManager::new(&cfg, 16);
        let device = Device::a5000();
        let bf16_weights = cfg.bf16_bytes();
        let df11_weights = (bf16_weights as f64 * 0.679) as u64;
        let free_bf16 = device.hbm_bytes.saturating_sub(bf16_weights);
        let free_df11 = device.hbm_bytes.saturating_sub(df11_weights);
        let t_bf16 = mgr.max_tokens_within(free_bf16, 1);
        let t_df11 = mgr.max_tokens_within(free_df11, 1);
        assert!(
            t_df11 as f64 > t_bf16 as f64 * 1.5,
            "DF11 {t_df11} vs BF16 {t_bf16}"
        );
    }
}
