//! Cross-backend container I/O: the golden fixture must decode to the
//! pinned CRC through every payload backend (buffered read, zero-copy
//! mmap, prefetch ring) × decode path (serial, pooled), corruption must
//! surface as the same typed errors on the new backends as on the old
//! one, ring completion order must never affect a decoded bit, and
//! NUMA-style pool pinning must change placement only — never output.

use dfloat11::bf16::Bf16;
use dfloat11::codec::{Codec, DecodeOpts, Df11Codec};
use dfloat11::container::{ContainerReader, ContainerWriter};
use dfloat11::coordinator::{ContainerSource, WeightSource};
use dfloat11::crc32::Hasher;
use dfloat11::error::Error;
use dfloat11::io::ring::RingDriver;
use dfloat11::rng::Rng;
use dfloat11::{IoBackend, WorkerPool};
use std::path::PathBuf;

/// Pinned CRC-32 of the golden fixture's decoded weights (see
/// `tests/golden.rs` — the constant must match there and here).
const GOLDEN_WEIGHTS_CRC32: u32 = 0x5fa90c47;
const GOLDEN_TENSOR_COUNT: usize = 5;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden.df11")
}

fn temp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("df11_io_backends_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.df11", std::process::id()))
}

fn gaussian_weights(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

/// CRC-32 over tensors' BF16 bits in the given order.
fn crc_of(tensors: &[Vec<Bf16>]) -> u32 {
    let mut h = Hasher::new();
    for t in tensors {
        for w in t {
            h.update(&w.to_bits().to_le_bytes());
        }
    }
    h.finalize()
}

/// Deterministic Fisher–Yates permutation of `0..n` (LCG-driven).
fn permuted(n: usize, seed: u32) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    let mut s = seed;
    for i in (1..n).rev() {
        s = s.wrapping_mul(1664525).wrapping_add(1013904223);
        let j = s as usize % (i + 1);
        order.swap(i, j);
    }
    order
}

/// A 4-group DF11 container in a temp file (the fixture holds raw-bf16
/// payloads; pooled-decode coverage needs real DF11 streams).
fn write_df11_grouped(tag: &str) -> (PathBuf, Vec<Vec<Bf16>>) {
    let mut writer = ContainerWriter::new("io-backends");
    let mut expect = Vec::new();
    for (g, n, seed) in [
        ("embed", 40_000usize, 21u64),
        ("block.0", 50_000, 22),
        ("block.1", 50_000, 23),
        ("lm_head", 45_000, 24),
    ] {
        let ws = gaussian_weights(n, seed);
        let t = Df11Codec::default().compress(&ws).unwrap();
        writer.push(g, &format!("{g}.w"), t.view());
        expect.push(ws);
    }
    let path = temp_path(tag);
    writer.write_to(&path).unwrap();
    (path, expect)
}

#[test]
fn golden_crc_is_identical_across_all_backends() {
    for backend in IoBackend::ALL {
        let reader = ContainerReader::open_with(&fixture_path(), backend)
            .unwrap_or_else(|e| panic!("open {backend}: {e}"));
        assert_eq!(reader.io_backend(), backend);
        let decoded: Vec<Vec<Bf16>> = (0..GOLDEN_TENSOR_COUNT)
            .map(|i| {
                reader
                    .read_tensor_at(i)
                    .unwrap()
                    .decompress(&DecodeOpts::default())
                    .unwrap()
            })
            .collect();
        assert_eq!(
            crc_of(&decoded),
            GOLDEN_WEIGHTS_CRC32,
            "backend {backend} drifted from the pinned golden CRC"
        );
    }
}

#[test]
fn df11_payloads_roundtrip_on_every_backend_and_decode_path() {
    let (path, expect) = write_df11_grouped("paths");
    for backend in IoBackend::ALL {
        let pool = WorkerPool::with_config(4, true);
        let serial = DecodeOpts::default();
        let pooled = DecodeOpts::with_pool(4, pool);
        for (label, opts) in [("serial", &serial), ("pooled", &pooled)] {
            let reader = ContainerReader::open_with(&path, backend).unwrap();
            let decoded: Vec<Vec<Bf16>> = (0..expect.len())
                .map(|i| reader.read_tensor_at(i).unwrap().decompress(opts).unwrap())
                .collect();
            assert_eq!(
                decoded, expect,
                "backend {backend} × {label} decode is not bit-identical"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn ring_completion_order_never_affects_decoded_bits() {
    // Adversarial completion orders on the deterministic synchronous
    // driver: submit every payload range, force-complete them in a
    // seeded permutation, consume in another permutation — the decoded
    // bits must be the pinned golden bits every time, because
    // completions are keyed by tag, never by position.
    for seed in 1u32..=6 {
        let reader = ContainerReader::open_with_driver(
            &fixture_path(),
            IoBackend::Ring,
            RingDriver::Synchronous,
        )
        .unwrap();
        let all: Vec<usize> = (0..GOLDEN_TENSOR_COUNT).collect();
        assert_eq!(reader.prefetch(&all), GOLDEN_TENSOR_COUNT);
        let ring = reader.ring().expect("ring backend has a ring");
        assert_eq!(ring.queued_tags().len(), GOLDEN_TENSOR_COUNT);

        for &i in &permuted(GOLDEN_TENSOR_COUNT, seed) {
            assert!(ring.force_complete(i as u64), "tag {i} was queued");
        }
        let mut decoded: Vec<Vec<Bf16>> = vec![Vec::new(); GOLDEN_TENSOR_COUNT];
        for &i in &permuted(GOLDEN_TENSOR_COUNT, seed.wrapping_mul(31).wrapping_add(7)) {
            decoded[i] = reader
                .read_tensor_at(i)
                .unwrap()
                .decompress(&DecodeOpts::default())
                .unwrap();
        }
        assert_eq!(
            crc_of(&decoded),
            GOLDEN_WEIGHTS_CRC32,
            "completion order (seed {seed}) changed decoded bits"
        );
        let stats = reader.ring_stats().unwrap();
        assert_eq!(stats.submitted, GOLDEN_TENSOR_COUNT as u64);
        assert_eq!(stats.ring_hits, GOLDEN_TENSOR_COUNT as u64);
        assert_eq!(stats.direct_reads, 0);
    }
}

#[test]
fn ring_prefetch_pipeline_serves_identical_weights() {
    // The engine-facing path: a ring-backed ContainerSource with
    // prefetch on must hand the decoder the same widened weights as the
    // plain buffered-read source, and the ring must actually have been
    // used (submissions and hits observed).
    let (path, _) = write_df11_grouped("pipeline");
    let names = ["embed.w", "block.0.w", "block.1.w", "lm_head.w"];

    let baseline = ContainerSource::open(&path).unwrap();
    let ring = ContainerSource::open_with(&path, IoBackend::Ring).unwrap();
    let mut staging = Vec::new();
    for name in names {
        let mut a = Vec::new();
        let mut b = Vec::new();
        baseline
            .fetch_into(name, &DecodeOpts::default(), &mut staging, &mut a)
            .unwrap();
        ring.fetch_into(name, &DecodeOpts::default(), &mut staging, &mut b)
            .unwrap();
        assert_eq!(a, b, "ring-served weights differ for {name}");
    }
    let stats = ring.reader().ring_stats().unwrap();
    assert!(stats.submitted > 0, "prefetch never submitted");
    assert!(stats.ring_hits > 0, "no fetch was served from the ring");

    // Prefetch off: the same bits, with the ring bypassed for
    // read-ahead (demand fetches may still consume it).
    let cold = ContainerSource::open_with(&path, IoBackend::Ring).unwrap();
    for name in names {
        let mut out = Vec::new();
        cold.fetch_into(
            name,
            &DecodeOpts::default().without_prefetch(),
            &mut staging,
            &mut out,
        )
        .unwrap();
        assert!(!out.is_empty());
    }
    assert_eq!(cold.reader().ring_stats().unwrap().submitted, 0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_payload_is_typed_on_every_backend() {
    for backend in IoBackend::ALL {
        let (path, _) = write_df11_grouped(&format!("trunc_{backend}"));
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        let reader = ContainerReader::open_with(&path, backend).unwrap();
        // The intact first group still reads; the cut one fails typed.
        assert!(reader.read_group("embed").is_ok(), "backend {backend}");
        let err = reader.read_group("lm_head").unwrap_err();
        assert!(
            matches!(err, Error::InvalidContainer(_)),
            "backend {backend}: got {err}"
        );
        std::fs::remove_file(&path).ok();
    }
}

/// Byte position of entry `k`'s offset field inside the header (same
/// walk as `tests/container.rs`).
fn offset_field_pos(reader: &ContainerReader, k: usize) -> usize {
    let mut pos = 4 + 4; // magic + version
    pos += 8 + reader.model_name().len(); // name
    pos += 4; // entry count
    for (i, e) in reader.entries().iter().enumerate() {
        pos += 8 + e.group.len(); // group
        pos += 8 + e.name.len(); // tensor name
        pos += 1; // codec id
        pos += 4 + 8 * e.shape.len(); // ndim + dims
        pos += 8; // num_elements
        if i == k {
            return pos;
        }
        pos += 8 + 8 + 4; // offset + len + crc
    }
    panic!("entry {k} out of range");
}

fn header_len(reader: &ContainerReader) -> usize {
    let last = reader.entries().len() - 1;
    offset_field_pos(reader, last) + 8 + 8 + 4 + 4
}

#[test]
fn range_past_eof_is_typed_on_every_backend() {
    // A CRC-valid index whose payload range points past EOF: the mmap
    // backend must refuse (not fault), and a ring prefetch of the bogus
    // range must park the typed error and surface it on consume.
    for backend in IoBackend::ALL {
        let (path, _) = write_df11_grouped(&format!("eof_{backend}"));
        let mut bytes = std::fs::read(&path).unwrap();
        let reader = ContainerReader::open(&path).unwrap();
        let k = reader.entries().len() - 1; // lm_head
        let pos = offset_field_pos(&reader, k);
        let hdr_len = header_len(&reader);
        drop(reader);
        let bogus = bytes.len() as u64 + 4096;
        bytes[pos..pos + 8].copy_from_slice(&bogus.to_le_bytes());
        let crc = dfloat11::crc32::crc32(&bytes[..hdr_len - 4]);
        bytes[hdr_len - 4..hdr_len].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let reader = ContainerReader::open_with(&path, backend).unwrap();
        if backend == IoBackend::Ring {
            // Put the poisoned range in flight first — the error must
            // arrive through the completion path too.
            reader.prefetch(&[k]);
        }
        let err = reader.read_group("lm_head").unwrap_err();
        assert!(
            matches!(err, Error::InvalidContainer(_)),
            "backend {backend}: got {err}"
        );
        assert!(reader.read_group("embed").is_ok(), "backend {backend}");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn mmap_shrunk_underneath_a_read_is_a_typed_error() {
    // Truncate the file *after* the mapping exists: touching the dead
    // tail of the map would be a fault, so the source must detect the
    // shrink and fail typed instead.
    let (path, _) = write_df11_grouped("shrink");
    let full = std::fs::metadata(&path).unwrap().len();
    let reader = ContainerReader::open_with(&path, IoBackend::Mmap).unwrap();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(full - 64).unwrap();
    drop(f);
    let err = reader.read_group("lm_head").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn pinned_pool_decode_is_bit_identical_and_counts_hops() {
    // NUMA-style pinning only moves which worker runs a stripe; every
    // socket configuration must decode the exact same bits, and the
    // hop clock must be exactly steals × the per-hop constant. The
    // tensors here sit above the parallel-decode threshold, so the
    // pinned two-phase pipeline genuinely runs.
    let (path, expect) = write_df11_grouped("pinned");
    for sockets in [1usize, 2, 4] {
        let pool = WorkerPool::with_pinning(8, true, sockets);
        assert_eq!(pool.pin_sockets(), sockets);
        let opts = DecodeOpts::with_pool(0, pool.clone());
        let reader = ContainerReader::open(&path).unwrap();
        let decoded: Vec<Vec<Bf16>> = (0..expect.len())
            .map(|i| reader.read_tensor_at(i).unwrap().decompress(&opts).unwrap())
            .collect();
        assert_eq!(
            decoded, expect,
            "pinning with {sockets} sockets changed decoded bits"
        );
        let hops = pool.cross_socket_steals();
        let per_hop = dfloat11::runtime::pool::NUMA_HOP_SECONDS;
        assert_eq!(pool.simulated_numa_hop_seconds(), hops as f64 * per_hop);
        if sockets == 1 {
            assert_eq!(hops, 0, "an unpinned pool cannot hop sockets");
        }
    }
    // More sockets than workers clamps to one worker per socket.
    assert_eq!(WorkerPool::with_pinning(2, true, 8).pin_sockets(), 2);
    std::fs::remove_file(&path).ok();
}
