//! The structured fuzz harness, bounded for normal `cargo test`.
//!
//! Three corpora, one acceptance bar: zero panics, zero
//! silent-corruption acceptances, zero backend divergence, zero
//! scheduler-invariant violations. The extended-budget pass is the
//! same code with `DF11_FUZZ_CASES` raised (the `fuzz-smoke` CI job);
//! every bug the harness has found is pinned forever by a recipe in
//! `tests/fuzz_corpus/`.

use dfloat11::fuzz::{
    apply_recipe, case_budget, check_bytes, fuzz_container_cases, fuzz_fleet_traces,
    fuzz_server_traces, reference_container,
};
use std::path::Path;

/// One knob scales every corpus: `DF11_FUZZ_CASES` is the container
/// budget; the trace corpora (which build engines per case) take a
/// proportional share.
fn budgets() -> (u32, u32, u32) {
    let container = case_budget(48);
    let fleet = (container / 6).max(4);
    let server = (container / 4).max(6);
    (container, fleet, server)
}

/// Container-bytes corpus: seeded generic mutations + structured
/// CRC-resealed header patches over all four codecs, judged across
/// all three I/O backends.
#[test]
fn container_fuzz_bounded() {
    let (cases, _, _) = budgets();
    let summary = fuzz_container_cases(42, cases)
        .unwrap_or_else(|e| panic!("container fuzz failed: {e}"));
    assert_eq!(summary.cases, cases);
    // The harness must actually be rejecting things: a run where every
    // mutation sailed through means the oracle went blind.
    assert!(
        summary.open_rejected as u64 + summary.entry_rejections > 0,
        "no mutation was rejected across {cases} cases: {summary:?}"
    );
}

/// Replay the checked-in regression corpus: every `.case` recipe (and
/// any raw `.bin` crash artifact) must be handled typed, identically
/// across backends, and must actually trigger a rejection — a case
/// that decodes fully clean pins nothing.
#[test]
fn corpus_recipes_replay_clean() {
    let reference = reference_container(42);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fuzz_corpus");
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .expect("corpus directory is checked in")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    paths.sort();
    let mut ran = 0u32;
    for path in paths {
        let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let bytes = match ext {
            "case" => {
                let recipe = std::fs::read_to_string(&path).expect("readable recipe");
                let mut b = reference.bytes.clone();
                apply_recipe(&mut b, &recipe).unwrap_or_else(|e| panic!("{name}: {e}"));
                b
            }
            "bin" => std::fs::read(&path).expect("readable crash artifact"),
            _ => continue,
        };
        let report = check_bytes(&format!("corpus{ran}"), &bytes, &reference)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            !report.opened || report.rejected > 0,
            "{name}: decoded fully clean — this corpus case pins nothing"
        );
        ran += 1;
    }
    assert!(ran >= 8, "expected the 8 seed corpus cases, replayed {ran}");
}

/// Scheduler-trace corpus, fleet level: random routers, health
/// schedules, queue bounds, and injected shard failures, with the
/// no-lost-requests / unique-ids / token-identity invariants.
#[test]
fn fleet_trace_fuzz_bounded() {
    let (_, cases, _) = budgets();
    let summary =
        fuzz_fleet_traces(42, cases).unwrap_or_else(|e| panic!("fleet trace fuzz failed: {e}"));
    assert_eq!(summary.cases, cases);
    assert!(
        summary.responses > 0,
        "no trace completed any request: {summary:?}"
    );
    assert!(
        summary.exact_checked > 0,
        "no response was token-checked by exact id: {summary:?}"
    );
}

/// Scheduler-trace corpus, single-box level: random policies, batch
/// sizes, and arrival traces — everything completes with reference
/// tokens.
#[test]
fn server_trace_fuzz_bounded() {
    let (_, _, cases) = budgets();
    let summary =
        fuzz_server_traces(42, cases).unwrap_or_else(|e| panic!("server trace fuzz failed: {e}"));
    assert_eq!(summary.cases, cases);
    assert!(summary.responses > 0 && summary.exact_checked == summary.responses);
}
