//! Integration tests for the `.df11` container: streaming reads in any
//! order, and typed errors for truncation, unknown codecs, version
//! mismatches, and checksum corruption.

use dfloat11::bf16::Bf16;
use dfloat11::codec::{all_codecs, Codec, DecodeOpts, Df11Codec, RansCodec, RawBf16Codec};
use dfloat11::container::{
    write_df11_model, ContainerReader, ContainerWriter, CONTAINER_VERSION,
};
use dfloat11::coordinator::{ContainerSource, WeightSource};
use dfloat11::dfloat11::{Df11Model, Df11Tensor, TensorGroup};
use dfloat11::error::Error;
use dfloat11::rng::Rng;
use std::path::PathBuf;

fn gaussian_weights(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

fn temp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("df11_container_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.df11", std::process::id()))
}

/// A 4-group model container: embed, block.0, block.1, lm_head.
fn write_grouped(tag: &str) -> (PathBuf, Df11Model) {
    let mut m = Df11Model::new("grouped");
    for (g, n, seed) in [
        ("embed", 1500usize, 1u64),
        ("block.0", 2000, 2),
        ("block.1", 2500, 3),
        ("lm_head", 1800, 4),
    ] {
        m.push_group(TensorGroup {
            name: g.to_string(),
            tensors: vec![(
                format!("{g}.w"),
                Df11Tensor::compress(&gaussian_weights(n, seed)).unwrap(),
            )],
        });
    }
    let path = temp_path(tag);
    write_df11_model(&path, &m).unwrap();
    (path, m)
}

#[test]
fn groups_stream_out_of_order() {
    let (path, model) = write_grouped("ooo");
    let reader = ContainerReader::open(&path).unwrap();
    let names: Vec<&str> = reader.group_names().iter().map(|s| s.as_str()).collect();
    assert_eq!(names, vec!["embed", "block.0", "block.1", "lm_head"]);
    // Read groups in scrambled order — the reader seeks per block.
    for g in ["lm_head", "block.0", "embed", "block.1"] {
        let group = reader.read_group(g).unwrap();
        let expect = model.group(g).unwrap().tensors[0].1.decompress().unwrap();
        assert_eq!(
            group.tensors[0].1.decompress(&DecodeOpts::default()).unwrap(),
            expect,
            "group {g}"
        );
    }
    // Re-reading an already-streamed group still works.
    assert!(reader.read_group("embed").is_ok());
    // A missing group is a typed error.
    assert!(matches!(
        reader.read_group("block.7"),
        Err(Error::InvalidArgument(_))
    ));
    std::fs::remove_file(&path).ok();
}

#[test]
fn single_tensor_reads_by_name() {
    let (path, model) = write_grouped("byname");
    let reader = ContainerReader::open(&path).unwrap();
    let t = reader.read_tensor("block.1.w").unwrap();
    let expect = model.group("block.1").unwrap().tensors[0].1.decompress().unwrap();
    assert_eq!(t.decompress(&DecodeOpts::default()).unwrap(), expect);
    assert!(reader.read_tensor("nope").is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_payload_is_a_typed_error() {
    let (path, _) = write_grouped("trunc_payload");
    let bytes = std::fs::read(&path).unwrap();
    // Cut into the last payload: the header still parses, streaming the
    // last group fails with a typed container error.
    std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert!(reader.read_group("embed").is_ok());
    let err = reader.read_group("lm_head").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_header_is_a_typed_error() {
    let (path, _) = write_grouped("trunc_header");
    let bytes = std::fs::read(&path).unwrap();
    // Cut mid-header (the header of a 4-tensor index is > 40 bytes).
    std::fs::write(&path, &bytes[..40]).unwrap();
    let err = ContainerReader::open(&path).unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn unknown_codec_id_is_a_typed_error() {
    let good = RawBf16Codec.compress(&gaussian_weights(64, 9)).unwrap();
    let opaque = vec![0x5Au8; 128];
    let mut writer = ContainerWriter::new("future");
    writer.push("g", "ok", good.view());
    writer
        .push_opaque("g", "future_block", 0x7F, vec![64], &opaque)
        .unwrap();
    let path = temp_path("unknown_codec");
    writer.write_to(&path).unwrap();
    // The index itself parses — codec ids are validated lazily so old
    // readers can still inspect (and partially serve) newer files.
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.entries().len(), 2);
    assert!(matches!(
        reader.read_group("g"),
        Err(Error::UnknownCodec(0x7F))
    ));
    // The known tensor is still readable on its own.
    assert!(reader.read_tensor("ok").is_ok());
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_mismatch_is_a_typed_error() {
    let (path, _) = write_grouped("version");
    let mut bytes = std::fs::read(&path).unwrap();
    // The version field sits right after the 4-byte magic.
    bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    match ContainerReader::open(&path) {
        Err(Error::UnsupportedVersion(got, supported)) => {
            assert_eq!(got, 99);
            assert_eq!(supported, CONTAINER_VERSION);
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn payload_crc_corruption_is_validation_not_panic() {
    let ws = gaussian_weights(5_000, 11);
    let mut writer = ContainerWriter::new("crc");
    let df11 = Df11Codec::default().compress(&ws).unwrap();
    let rans = RansCodec.compress(&ws).unwrap();
    writer.push("g", "df11", df11.view());
    writer.push("g", "rans", rans.view());
    let path = temp_path("crc");
    let summary = writer.write_to(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip one bit in the first payload byte.
    let pos = summary.header_bytes as usize;
    bytes[pos] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let err = reader.read_tensor("df11").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    // The untouched block still reads and roundtrips.
    let t = reader.read_tensor("rans").unwrap();
    assert_eq!(t.decompress(&DecodeOpts::default()).unwrap(), ws);
    std::fs::remove_file(&path).ok();
}

/// Byte position of entry `k`'s offset field inside the header, walked
/// from the parsed index metadata (every field is fixed-width except
/// the length-prefixed strings).
fn offset_field_pos(reader: &ContainerReader, k: usize) -> usize {
    let mut pos = 4 + 4; // magic + version
    pos += 8 + reader.model_name().len(); // name
    pos += 4; // entry count
    for (i, e) in reader.entries().iter().enumerate() {
        pos += 8 + e.group.len(); // group
        pos += 8 + e.name.len(); // tensor name
        pos += 1; // codec id
        pos += 4 + 8 * e.shape.len(); // ndim + dims
        pos += 8; // num_elements
        if i == k {
            return pos;
        }
        pos += 8 + 8 + 4; // offset + len + crc
    }
    panic!("entry {k} out of range");
}

/// Header byte length: last entry's walk end + its tail fields + the
/// trailing header CRC.
fn header_len(reader: &ContainerReader) -> usize {
    let last = reader.entries().len() - 1;
    offset_field_pos(reader, last) + 8 + 8 + 4 + 4
}

#[test]
fn truncation_mid_group_fails_typed_and_isolates() {
    // A group with several tensors, the file cut inside the group's
    // *second* tensor: streaming the group is a typed error, while the
    // intact first tensor still reads — never a wrong-weight decode.
    let mut writer = ContainerWriter::new("midgroup");
    let a = Df11Codec::default().compress(&gaussian_weights(2_000, 31)).unwrap();
    let b = Df11Codec::default().compress(&gaussian_weights(2_000, 32)).unwrap();
    writer.push("block.0", "block.0.a", a.view());
    writer.push("block.0", "block.0.b", b.view());
    let path = temp_path("midgroup");
    writer.write_to(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let cut = (reader.entries()[1].offset + reader.entries()[1].len / 2) as usize;
    drop(reader);
    std::fs::write(&path, &bytes[..cut]).unwrap();

    let reader = ContainerReader::open(&path).unwrap();
    let err = reader.read_group("block.0").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    let ok = reader.read_tensor("block.0.a").unwrap();
    assert!(ok.decompress(&DecodeOpts::default()).is_ok());
    std::fs::remove_file(&path).ok();
}

#[test]
fn group_range_past_eof_is_a_typed_error() {
    // A (CRC-valid) index whose payload range points past EOF — the
    // shape of bug a mis-assigned shard range read would hit. The read
    // must surface a typed truncation error, never parse garbage.
    let (path, _) = write_grouped("past_eof");
    let mut bytes = std::fs::read(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let k = reader.entries().len() - 1; // lm_head
    let pos = offset_field_pos(&reader, k);
    let hdr_len = header_len(&reader);
    drop(reader);
    let bogus = bytes.len() as u64 + 4096;
    bytes[pos..pos + 8].copy_from_slice(&bogus.to_le_bytes());
    // Re-seal the header CRC so only the range itself is "wrong".
    let crc = dfloat11::crc32::crc32(&bytes[..hdr_len - 4]);
    bytes[hdr_len - 4..hdr_len].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();

    let reader = ContainerReader::open(&path).expect("header is self-consistent");
    let err = reader.read_group("lm_head").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    // Groups with in-range payloads are unaffected.
    assert!(reader.read_group("embed").is_ok());
    std::fs::remove_file(&path).ok();
}

#[test]
fn crc_corruption_in_one_shards_slice_is_isolated() {
    // Flip a bit inside block.1's payload: the shard scoped to block.1
    // must get a typed CRC error on fetch, while the shard scoped to
    // the untouched groups serves every one of its tensors.
    let (path, _) = write_grouped("shard_slice");
    let reader = ContainerReader::open(&path).unwrap();
    let idx = reader.find("block.1.w").unwrap();
    let target = reader.entries()[idx].offset + reader.entries()[idx].len / 2;
    drop(reader);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[target as usize] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    let healthy =
        ContainerSource::open_scoped(&path, &["embed".to_string(), "block.0".to_string()])
            .unwrap();
    let poisoned = ContainerSource::open_scoped(&path, &["block.1".to_string()]).unwrap();
    let mut staging = Vec::new();
    let mut out = Vec::new();
    for name in ["embed.w", "block.0.w"] {
        healthy
            .fetch_into(name, &DecodeOpts::default(), &mut staging, &mut out)
            .unwrap_or_else(|e| panic!("healthy shard tensor {name}: {e}"));
        assert!(!out.is_empty());
    }
    let err = poisoned
        .fetch_into("block.1.w", &DecodeOpts::default(), &mut staging, &mut out)
        .unwrap_err();
    assert!(
        matches!(err, Error::InvalidContainer(_)),
        "corruption must be a typed error, got {err}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn mixed_codec_container_roundtrips() {
    let ws = gaussian_weights(3_000, 12);
    let mut writer = ContainerWriter::new("mixed");
    let parts: Vec<_> = all_codecs()
        .iter()
        .map(|c| (c.name(), c.compress(&ws).unwrap()))
        .collect();
    for (name, p) in &parts {
        writer.push("g", name, p.view());
    }
    let path = temp_path("mixed");
    writer.write_to(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let group = reader.read_group("g").unwrap();
    assert_eq!(group.tensors.len(), 4);
    for (name, t) in &group.tensors {
        assert_eq!(
            t.decompress(&DecodeOpts::with_threads(2)).unwrap(),
            ws,
            "codec {name}"
        );
    }
    // Index metadata reflects the codec mix.
    let ids: Vec<u8> = reader.entries().iter().map(|e| e.codec_id).collect();
    assert_eq!(ids.len(), 4);
    assert!(ids.contains(&0) && ids.contains(&1) && ids.contains(&2) && ids.contains(&3));
    std::fs::remove_file(&path).ok();
}
