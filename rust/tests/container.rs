//! Integration tests for the `.df11` container: streaming reads in any
//! order, and typed errors for truncation, unknown codecs, version
//! mismatches, and checksum corruption.

use dfloat11::bf16::Bf16;
use dfloat11::codec::{all_codecs, Codec, DecodeOpts, Df11Codec, RansCodec, RawBf16Codec};
use dfloat11::container::{
    write_df11_model, ContainerReader, ContainerWriter, CONTAINER_VERSION,
};
use dfloat11::dfloat11::{Df11Model, Df11Tensor, TensorGroup};
use dfloat11::error::Error;
use dfloat11::rng::Rng;
use std::path::PathBuf;

fn gaussian_weights(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

fn temp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("df11_container_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.df11", std::process::id()))
}

/// A 4-group model container: embed, block.0, block.1, lm_head.
fn write_grouped(tag: &str) -> (PathBuf, Df11Model) {
    let mut m = Df11Model::new("grouped");
    for (g, n, seed) in [
        ("embed", 1500usize, 1u64),
        ("block.0", 2000, 2),
        ("block.1", 2500, 3),
        ("lm_head", 1800, 4),
    ] {
        m.push_group(TensorGroup {
            name: g.to_string(),
            tensors: vec![(
                format!("{g}.w"),
                Df11Tensor::compress(&gaussian_weights(n, seed)).unwrap(),
            )],
        });
    }
    let path = temp_path(tag);
    write_df11_model(&path, &m).unwrap();
    (path, m)
}

#[test]
fn groups_stream_out_of_order() {
    let (path, model) = write_grouped("ooo");
    let reader = ContainerReader::open(&path).unwrap();
    let names: Vec<&str> = reader.group_names().iter().map(|s| s.as_str()).collect();
    assert_eq!(names, vec!["embed", "block.0", "block.1", "lm_head"]);
    // Read groups in scrambled order — the reader seeks per block.
    for g in ["lm_head", "block.0", "embed", "block.1"] {
        let group = reader.read_group(g).unwrap();
        let expect = model.group(g).unwrap().tensors[0].1.decompress().unwrap();
        assert_eq!(
            group.tensors[0].1.decompress(&DecodeOpts::default()).unwrap(),
            expect,
            "group {g}"
        );
    }
    // Re-reading an already-streamed group still works.
    assert!(reader.read_group("embed").is_ok());
    // A missing group is a typed error.
    assert!(matches!(
        reader.read_group("block.7"),
        Err(Error::InvalidArgument(_))
    ));
    std::fs::remove_file(&path).ok();
}

#[test]
fn single_tensor_reads_by_name() {
    let (path, model) = write_grouped("byname");
    let reader = ContainerReader::open(&path).unwrap();
    let t = reader.read_tensor("block.1.w").unwrap();
    let expect = model.group("block.1").unwrap().tensors[0].1.decompress().unwrap();
    assert_eq!(t.decompress(&DecodeOpts::default()).unwrap(), expect);
    assert!(reader.read_tensor("nope").is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_payload_is_a_typed_error() {
    let (path, _) = write_grouped("trunc_payload");
    let bytes = std::fs::read(&path).unwrap();
    // Cut into the last payload: the header still parses, streaming the
    // last group fails with a typed container error.
    std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert!(reader.read_group("embed").is_ok());
    let err = reader.read_group("lm_head").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_header_is_a_typed_error() {
    let (path, _) = write_grouped("trunc_header");
    let bytes = std::fs::read(&path).unwrap();
    // Cut mid-header (the header of a 4-tensor index is > 40 bytes).
    std::fs::write(&path, &bytes[..40]).unwrap();
    let err = ContainerReader::open(&path).unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn unknown_codec_id_is_a_typed_error() {
    let good = RawBf16Codec.compress(&gaussian_weights(64, 9)).unwrap();
    let opaque = vec![0x5Au8; 128];
    let mut writer = ContainerWriter::new("future");
    writer.push("g", "ok", good.view());
    writer.push_opaque("g", "future_block", 0x7F, vec![64], &opaque);
    let path = temp_path("unknown_codec");
    writer.write_to(&path).unwrap();
    // The index itself parses — codec ids are validated lazily so old
    // readers can still inspect (and partially serve) newer files.
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.entries().len(), 2);
    assert!(matches!(
        reader.read_group("g"),
        Err(Error::UnknownCodec(0x7F))
    ));
    // The known tensor is still readable on its own.
    assert!(reader.read_tensor("ok").is_ok());
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_mismatch_is_a_typed_error() {
    let (path, _) = write_grouped("version");
    let mut bytes = std::fs::read(&path).unwrap();
    // The version field sits right after the 4-byte magic.
    bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    match ContainerReader::open(&path) {
        Err(Error::UnsupportedVersion(got, supported)) => {
            assert_eq!(got, 99);
            assert_eq!(supported, CONTAINER_VERSION);
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn payload_crc_corruption_is_validation_not_panic() {
    let ws = gaussian_weights(5_000, 11);
    let mut writer = ContainerWriter::new("crc");
    let df11 = Df11Codec::default().compress(&ws).unwrap();
    let rans = RansCodec.compress(&ws).unwrap();
    writer.push("g", "df11", df11.view());
    writer.push("g", "rans", rans.view());
    let path = temp_path("crc");
    let summary = writer.write_to(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip one bit in the first payload byte.
    let pos = summary.header_bytes as usize;
    bytes[pos] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let err = reader.read_tensor("df11").unwrap_err();
    assert!(matches!(err, Error::InvalidContainer(_)), "got {err}");
    // The untouched block still reads and roundtrips.
    let t = reader.read_tensor("rans").unwrap();
    assert_eq!(t.decompress(&DecodeOpts::default()).unwrap(), ws);
    std::fs::remove_file(&path).ok();
}

#[test]
fn mixed_codec_container_roundtrips() {
    let ws = gaussian_weights(3_000, 12);
    let mut writer = ContainerWriter::new("mixed");
    let parts: Vec<_> = all_codecs()
        .iter()
        .map(|c| (c.name(), c.compress(&ws).unwrap()))
        .collect();
    for (name, p) in &parts {
        writer.push("g", name, p.view());
    }
    let path = temp_path("mixed");
    writer.write_to(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let group = reader.read_group("g").unwrap();
    assert_eq!(group.tensors.len(), 3);
    for (name, t) in &group.tensors {
        assert_eq!(
            t.decompress(&DecodeOpts { threads: 2 }).unwrap(),
            ws,
            "codec {name}"
        );
    }
    // Index metadata reflects the codec mix.
    let ids: Vec<u8> = reader.entries().iter().map(|e| e.codec_id).collect();
    assert_eq!(ids.len(), 3);
    assert!(ids.contains(&0) && ids.contains(&1) && ids.contains(&2));
    std::fs::remove_file(&path).ok();
}
