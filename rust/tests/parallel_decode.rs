//! Edge-case coverage for the parallel two-phase decompression
//! pipeline, through public APIs only: degenerate tensors, chunk/thread
//! geometry corners, and sequential-equivalence at every pool width.

use dfloat11::bf16::Bf16;
use dfloat11::dfloat11::decompress::decompress_sequential;
use dfloat11::dfloat11::parallel::{decompress_parallel, decompress_parallel_into};
use dfloat11::gpu_sim::KernelConfig;
use dfloat11::rng::Rng;
use dfloat11::Df11Tensor;

fn gaussian(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

/// Empty tensors are rejected at compression (the container format has
/// no empty representation), so the parallel pipeline never sees one —
/// both entry points agree on the contract.
#[test]
fn empty_tensor_rejected_at_compression() {
    assert!(Df11Tensor::compress(&[]).is_err());
    // And an output-size mismatch against a real container is an error,
    // not a truncated decode.
    let t = Df11Tensor::compress(&gaussian(64, 1)).unwrap();
    let mut empty: Vec<Bf16> = Vec::new();
    assert!(decompress_parallel_into(&t, &mut empty, 4).is_err());
}

/// A tensor whose whole stream fits in one data chunk (remaining chunks
/// are tail padding with the gap-31 sentinel) decodes correctly at any
/// pool width.
#[test]
fn single_data_chunk() {
    // ~10 elements at ~3 bits/exponent ≈ 30 bits — far below one
    // 8-byte chunk.
    let ws = gaussian(10, 2);
    let config = KernelConfig {
        threads_per_block: 4,
        bytes_per_thread: 8,
        parallelism: 1,
    };
    let t = Df11Tensor::compress_shaped(&ws, &[ws.len()], &config).unwrap();
    let seq = decompress_sequential(&t).unwrap();
    assert_eq!(seq, ws);
    for threads in [1usize, 2, 4, 16] {
        assert_eq!(decompress_parallel(&t, threads).unwrap(), seq, "threads={threads}");
    }
}

/// Single-element tensor: the smallest legal container.
#[test]
fn single_element_tensor() {
    let ws = vec![Bf16::from_f32(-0.375)];
    let t = Df11Tensor::compress(&ws).unwrap();
    for threads in [1usize, 2, 8] {
        assert_eq!(decompress_parallel(&t, threads).unwrap(), ws);
    }
}

/// Chunk counts that do not divide evenly by the worker count: the
/// last worker gets a short stripe, and stripes wider than the chunk
/// count clamp down.
#[test]
fn chunk_count_not_divisible_by_threads() {
    let ws = gaussian(30_000, 3);
    let config = KernelConfig {
        threads_per_block: 8,
        bytes_per_thread: 4,
        parallelism: 1,
    };
    let t = Df11Tensor::compress_shaped(&ws, &[ws.len()], &config).unwrap();
    let chunks = t.aux().gaps.len();
    let seq = decompress_sequential(&t).unwrap();
    for threads in [3usize, 5, 7, 11, 13, chunks - 1, chunks, chunks + 5] {
        let mut out = vec![Bf16::from_bits(0); ws.len()];
        let stats = decompress_parallel_into(&t, &mut out, threads).unwrap();
        assert_eq!(out, seq, "threads={threads}");
        assert!(stats.threads <= threads.max(1));
        assert!(stats.threads <= chunks);
        assert_eq!(stats.chunks, chunks);
    }
}

/// One-thread parallel execution still runs the full two-phase
/// pipeline and must equal the sequential decoder bit-for-bit.
#[test]
fn one_thread_parallel_equals_sequential() {
    for n in [1usize, 13, 257, 20_000] {
        let ws = gaussian(n, 100 + n as u64);
        let t = Df11Tensor::compress(&ws).unwrap();
        let seq = decompress_sequential(&t).unwrap();
        let mut out = vec![Bf16::from_bits(0); n];
        let stats = decompress_parallel_into(&t, &mut out, 1).unwrap();
        assert_eq!(out, seq, "n={n}");
        assert_eq!(stats.threads, 1);
    }
}

/// Codes wider than a whole chunk: exact power-of-two frequencies give
/// code lengths 1..=18 (two 18-bit codes) — longer than both the
/// 16-bit fast-table window and a whole 2-byte chunk, so codes straddle
/// chunk boundaries and some interior chunks contain no code start at
/// all (gap sentinel pointing past the chunk end). The parallel
/// pipeline must reproduce the sequential decode exactly.
#[test]
fn long_codes_straddling_chunk_boundaries() {
    let mut exps = Vec::with_capacity(1 << 18);
    for i in 0..18u32 {
        let sym = 60 + i as u8;
        for _ in 0..(1usize << (17 - i)) {
            exps.push(sym);
        }
    }
    exps.push(90); // the second deepest singleton, completing the tree
    // Interleave so deep codes appear throughout the stream.
    let mut rng = Rng::new(7);
    for i in (1..exps.len()).rev() {
        exps.swap(i, rng.next_index(i + 1));
    }
    let ws: Vec<Bf16> = exps
        .iter()
        .enumerate()
        .map(|(i, &e)| Bf16::from_parts(e, (i * 131 % 256) as u8))
        .collect();
    let config = KernelConfig {
        threads_per_block: 4,
        bytes_per_thread: 2,
        parallelism: 1,
    };
    let t = Df11Tensor::compress_shaped(&ws, &[ws.len()], &config).unwrap();
    assert!(
        t.codebook().max_len() > 16,
        "expected codes longer than a 16-bit chunk, got L={}",
        t.codebook().max_len()
    );
    let seq = decompress_sequential(&t).unwrap();
    assert_eq!(seq, ws);
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(decompress_parallel(&t, threads).unwrap(), seq, "threads={threads}");
    }
}

/// The serving-grade geometry (paper T=256, n=8) at a realistic tensor
/// size, swept across pool widths.
#[test]
fn paper_geometry_thread_sweep() {
    let ws = gaussian(300_000, 4);
    let config = KernelConfig {
        threads_per_block: 256,
        bytes_per_thread: 8,
        parallelism: 1,
    };
    let t = Df11Tensor::compress_shaped(&ws, &[ws.len()], &config).unwrap();
    let seq = decompress_sequential(&t).unwrap();
    assert_eq!(seq, ws);
    for threads in [1usize, 2, 4, 8] {
        assert_eq!(decompress_parallel(&t, threads).unwrap(), seq, "threads={threads}");
    }
}
