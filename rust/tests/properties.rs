//! Property-based tests over the crate's core invariants, driven by
//! `proptest_lite` (the vendored set has no proptest).

use dfloat11::bf16::{merge_planes, split_planes, Bf16};
use dfloat11::coordinator::{Request, RequestQueue};
use dfloat11::dfloat11::decompress::decompress_sequential;
use dfloat11::dfloat11::parallel::decompress_parallel;
use dfloat11::dfloat11::serial::{pack_gaps, unpack_gaps};
use dfloat11::dfloat11::Df11Tensor;
use dfloat11::gpu_sim::prefix_sum::{blelloch_exclusive_scan, serial_exclusive_scan};
use dfloat11::gpu_sim::KernelConfig;
use dfloat11::huffman::canonical::is_prefix_free;
use dfloat11::huffman::{decode_all, encode_symbols, Codebook};
use dfloat11::proptest_lite::{check, Config};
use dfloat11::rng::Rng;

fn cfg(cases: u32, max_size: usize) -> Config {
    Config {
        cases,
        max_size,
        ..Config::default()
    }
}

/// Arbitrary BF16 tensors — including NaN/Inf patterns — roundtrip
/// bit-exactly through compress + both decoders.
#[test]
fn prop_df11_roundtrip_arbitrary_bits() {
    check("df11-roundtrip", cfg(40, 20_000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let t = Df11Tensor::compress(&ws).map_err(|e| e.to_string())?;
        let kernel = t.decompress().map_err(|e| e.to_string())?;
        if kernel != ws {
            return Err(format!("kernel mismatch at n={n}"));
        }
        let seq = decompress_sequential(&t).map_err(|e| e.to_string())?;
        if seq != ws {
            return Err(format!("sequential mismatch at n={n}"));
        }
        Ok(())
    });
}

/// The parallel two-phase pipeline is bit-identical to the sequential
/// decoder for arbitrary bit patterns, kernel geometries, and thread
/// counts — the `seq == parallel` losslessness gate run by CI.
#[test]
fn prop_parallel_equals_sequential() {
    check("df11-seq-parallel-equivalence", cfg(30, 20_000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let t_per_block = [4usize, 8, 64, 256][g.usize_in(0, 3)];
        let n_bytes = [2usize, 4, 8, 16][g.usize_in(0, 3)];
        let config = KernelConfig {
            threads_per_block: t_per_block,
            bytes_per_thread: n_bytes,
            parallelism: 1,
        };
        let t = Df11Tensor::compress_shaped(&ws, &[n], &config).map_err(|e| e.to_string())?;
        let seq = decompress_sequential(&t).map_err(|e| e.to_string())?;
        if seq != ws {
            return Err(format!("sequential mismatch at n={n}"));
        }
        let threads = 1 + g.usize_in(0, 7);
        let par = decompress_parallel(&t, threads).map_err(|e| e.to_string())?;
        if par != seq {
            return Err(format!(
                "parallel != sequential (threads={threads}, T={t_per_block}, n={n_bytes}, len={n})"
            ));
        }
        Ok(())
    });
}

/// Gaussian tensors (realistic exponent skew) roundtrip across random
/// kernel geometries.
#[test]
fn prop_df11_roundtrip_random_geometry() {
    check("df11-geometry", cfg(30, 30_000), |g| {
        let n = g.len().max(8);
        let t_per_block = [4usize, 8, 32, 256][g.usize_in(0, 3)];
        let n_bytes = [2usize, 4, 8, 16][g.usize_in(0, 3)];
        let mut rng = Rng::new(g.rng.next_u64());
        let mut xs = vec![0f32; n];
        rng.fill_gaussian_f32(&mut xs, 0.02);
        let ws: Vec<Bf16> = xs.into_iter().map(Bf16::from_f32).collect();
        let config = KernelConfig {
            threads_per_block: t_per_block,
            bytes_per_thread: n_bytes,
            parallelism: 1 + g.usize_in(0, 2),
        };
        let t = Df11Tensor::compress_shaped(&ws, &[n], &config).map_err(|e| e.to_string())?;
        let mut out = vec![Bf16::from_bits(0); n];
        t.decompress_with(&mut out, &config)
            .map_err(|e| e.to_string())?;
        if out != ws {
            return Err(format!("mismatch T={t_per_block} n={n_bytes} len={n}"));
        }
        Ok(())
    });
}

/// Huffman codebooks from arbitrary frequency tables are prefix-free,
/// Kraft-tight, and decode what they encode.
#[test]
fn prop_huffman_prefix_free_and_roundtrip() {
    check("huffman-prefix-free", cfg(60, 2000), |g| {
        let alphabet = 1 + g.usize_in(0, 255);
        let n = g.len();
        let syms: Vec<u8> = g.vec_of(n, |r| (r.next_index(alphabet)) as u8);
        let mut freqs = [0u64; 256];
        for &s in &syms {
            freqs[s as usize] += 1;
        }
        let cb = Codebook::from_frequencies(&freqs).map_err(|e| e.to_string())?;
        if !is_prefix_free(cb.canonical()) {
            return Err("not prefix free".into());
        }
        if cb.kraft_sum() > 1.0 + 1e-9 {
            return Err(format!("kraft {} > 1", cb.kraft_sum()));
        }
        let (bytes, bits) = encode_symbols(&cb, &syms).map_err(|e| e.to_string())?;
        let back = decode_all(&cb, &bytes, bits).map_err(|e| e.to_string())?;
        if back != syms {
            return Err("decode mismatch".into());
        }
        Ok(())
    });
}

/// The Blelloch scan equals the serial scan for arbitrary inputs.
#[test]
fn prop_blelloch_equals_serial() {
    check("blelloch", cfg(80, 3000), |g| {
        let n = g.usize_in(0, g.size);
        let xs: Vec<u32> = g.vec_of(n, |r| r.next_u32());
        if blelloch_exclusive_scan(&xs) != serial_exclusive_scan(&xs) {
            return Err(format!("scan mismatch at n={n}"));
        }
        Ok(())
    });
}

/// BF16 plane split/merge is the identity for arbitrary bit patterns.
#[test]
fn prop_plane_split_merge_identity() {
    check("planes", cfg(50, 5000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let (e, sm) = split_planes(&ws);
        if merge_planes(&e, &sm) != ws {
            return Err("plane roundtrip broke".into());
        }
        Ok(())
    });
}

/// 5-bit gap packing roundtrips for arbitrary gap arrays.
#[test]
fn prop_gap_packing_roundtrip() {
    check("gap-pack", cfg(60, 4000), |g| {
        let n = g.usize_in(0, g.size);
        let gaps: Vec<u8> = g.vec_of(n, |r| (r.next_index(32)) as u8);
        let packed = pack_gaps(&gaps);
        let back = unpack_gaps(&packed, n).map_err(|e| e.to_string())?;
        if back != gaps {
            return Err("gap roundtrip broke".into());
        }
        Ok(())
    });
}

/// Queue invariants: FIFO order preserved, head always scheduled, no
/// request lost or duplicated under random batch sizes.
#[test]
fn prop_queue_never_starves_or_duplicates() {
    check("queue", cfg(50, 200), |g| {
        let mut q = RequestQueue::new();
        let n = g.usize_in(1, g.size.max(2));
        for i in 0..n {
            q.push(Request::new(vec![i as u32], 1), i as f64)
                .expect("queue-assigned ids");
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            let head = q.queued_ids()[0];
            let batch = q.next_batch(1 + g.usize_in(0, 7));
            if batch.is_empty() {
                return Err("empty batch with non-empty queue".into());
            }
            if batch[0].id != head {
                return Err("head was starved".into());
            }
            seen.extend(batch.into_iter().map(|r| r.id));
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != n || seen.len() != n {
            return Err(format!("lost/duplicated: {} of {n}", seen.len()));
        }
        if !seen.windows(2).all(|w| w[0] < w[1]) {
            return Err("FIFO order violated".into());
        }
        Ok(())
    });
}

/// Compressed size is always within sane bounds: never larger than
/// ~original + overhead, never below the entropy bound.
#[test]
fn prop_compressed_size_bounds() {
    check("size-bounds", cfg(30, 60_000), |g| {
        let n = g.len().max(1000);
        let mut rng = Rng::new(g.rng.next_u64());
        let mut xs = vec![0f32; n];
        rng.fill_gaussian_f32(&mut xs, 0.05);
        let ws: Vec<Bf16> = xs.into_iter().map(Bf16::from_f32).collect();
        let t = Df11Tensor::compress(&ws).map_err(|e| e.to_string())?;
        let entropy = dfloat11::entropy::component_entropy(&ws);
        let lower = (entropy.exponent_bits * n as f64 / 8.0) as u64 + n as u64; // exp + sm planes
        let upper = (n as u64) * 2 + 8192 + n as u64 / 4; // original + overhead
        let c = t.compressed_bytes();
        if c < lower {
            return Err(format!("compressed {c} below entropy bound {lower}"));
        }
        if c > upper {
            return Err(format!("compressed {c} above upper bound {upper}"));
        }
        Ok(())
    });
}

/// Every codec roundtrips bit-exactly through the on-disk container —
/// including mixed-codec containers whose per-tensor codecs are sampled
/// at random and a block picked by the `auto` selector: compress →
/// write container → stream back → decompress equals the source, and a
/// corrupted payload CRC fails with a typed validation error (never a
/// panic).
#[test]
fn prop_container_roundtrip() {
    use dfloat11::codec::all_codecs;
    use dfloat11::codec::select::{CodecSelector, SelectionPolicy};
    use dfloat11::codec::DecodeOpts;
    use dfloat11::container::{ContainerReader, ContainerWriter};
    use dfloat11::error::Error;

    let dir = std::env::temp_dir().join(format!("df11_prop_container_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut case = 0u64;
    check("container-roundtrip", cfg(10, 4000), |g| {
        case += 1;
        let path = dir.join(format!("case_{case}.df11"));
        let n = g.len();
        // Arbitrary bit patterns, NaN/Inf included.
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let codecs = all_codecs();
        let parts: Vec<_> = codecs
            .iter()
            .map(|c| c.compress(&ws).map(|p| (c.name(), p)))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        // A mixed group whose per-tensor codecs are sampled at random,
        // plus a block picked by the auto selector.
        let mixed: Vec<(String, dfloat11::CompressedTensor)> = (0..3)
            .map(|i| {
                let c = &codecs[g.usize_in(0, codecs.len() - 1)];
                c.compress(&ws).map(|p| (format!("mixed.t{i}"), p))
            })
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        let selector = CodecSelector::new(SelectionPolicy::Auto);
        let (auto_parts, record) = selector
            .select_shaped("auto", "auto.t", &ws, &[n])
            .map_err(|e| e.to_string())?;
        if auto_parts.codec_id() != record.codec {
            return Err("selection record disagrees with the payload codec".into());
        }
        let mut writer = ContainerWriter::new("prop");
        for (name, p) in &parts {
            writer.push(name, name, p.view());
        }
        for (name, p) in &mixed {
            writer.push("mixed", name, p.view());
        }
        writer.push("auto", "auto.t", auto_parts.view());
        let summary = writer.write_to(&path).map_err(|e| e.to_string())?;

        // Roundtrip: stream groups back, decompress, compare bit-exact.
        let reader = ContainerReader::open(&path).map_err(|e| e.to_string())?;
        let threads = 1 + g.usize_in(0, 3);
        for group in reader.groups() {
            let group = group.map_err(|e| e.to_string())?;
            for (name, t) in &group.tensors {
                let back = t
                    .decompress(&DecodeOpts::with_threads(threads))
                    .map_err(|e| e.to_string())?;
                if back != ws {
                    return Err(format!("codec {name} not lossless at n={n}"));
                }
            }
        }
        drop(reader);

        // Corrupt one payload byte: the read must fail with the typed
        // container-validation error, not a panic or silent corruption.
        let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let payload_start = summary.header_bytes as usize;
        let flip = payload_start + g.usize_in(0, (bytes.len() - payload_start).saturating_sub(1));
        bytes[flip] ^= 0x10;
        std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
        let reader = ContainerReader::open(&path).map_err(|e| e.to_string())?;
        let mut failed = false;
        for group in reader.groups() {
            match group {
                Ok(_) => {}
                Err(Error::InvalidContainer(_)) => failed = true,
                Err(other) => return Err(format!("expected validation error, got {other}")),
            }
        }
        if !failed {
            return Err("corrupted payload byte went undetected".into());
        }
        std::fs::remove_file(&path).ok();
        Ok(())
    });
}

/// rANS roundtrips arbitrary byte streams.
#[test]
fn prop_rans_roundtrip() {
    check("rans", cfg(40, 10_000), |g| {
        let n = g.len();
        let skew = g.usize_in(1, 8);
        let data: Vec<u8> = g.vec_of(n, |r| (r.next_index(1 << skew)) as u8);
        let model = dfloat11::ans::RansModel::from_data(&data);
        let enc = dfloat11::ans::rans_encode(&model, &data).map_err(|e| e.to_string())?;
        let dec =
            dfloat11::ans::rans_decode(&model, &enc, data.len()).map_err(|e| e.to_string())?;
        if dec != data {
            return Err("rans roundtrip broke".into());
        }
        Ok(())
    });
}
