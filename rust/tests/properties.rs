//! Property-based tests over the crate's core invariants, driven by
//! `proptest_lite` (the vendored set has no proptest).

use dfloat11::bf16::{merge_planes, split_planes, Bf16};
use dfloat11::coordinator::{
    BlockCacheMode, Engine, Request, RequestQueue, SchedulerConfig, Server, WeightMode,
};
use dfloat11::dfloat11::decompress::decompress_sequential;
use dfloat11::dfloat11::parallel::decompress_parallel;
use dfloat11::dfloat11::serial::{pack_gaps, unpack_gaps};
use dfloat11::dfloat11::Df11Tensor;
use dfloat11::fuzz::Mutator;
use dfloat11::gpu_sim::prefix_sum::{blelloch_exclusive_scan, serial_exclusive_scan};
use dfloat11::gpu_sim::KernelConfig;
use dfloat11::huffman::canonical::is_prefix_free;
use dfloat11::huffman::decode::decode_all_scalar;
use dfloat11::huffman::{decode_all, encode_symbols, BitCursor, Codebook, FastLut, HierarchicalLut};
use dfloat11::model::ModelConfig;
use dfloat11::proptest_lite::{check, Config, Gen};
use dfloat11::rng::Rng;

fn cfg(cases: u32, max_size: usize) -> Config {
    Config {
        cases,
        max_size,
        ..Config::default()
    }
}

/// Arbitrary BF16 tensors — including NaN/Inf patterns — roundtrip
/// bit-exactly through compress + both decoders.
#[test]
fn prop_df11_roundtrip_arbitrary_bits() {
    check("df11-roundtrip", cfg(40, 20_000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let t = Df11Tensor::compress(&ws).map_err(|e| e.to_string())?;
        let kernel = t.decompress().map_err(|e| e.to_string())?;
        if kernel != ws {
            return Err(format!("kernel mismatch at n={n}"));
        }
        let seq = decompress_sequential(&t).map_err(|e| e.to_string())?;
        if seq != ws {
            return Err(format!("sequential mismatch at n={n}"));
        }
        Ok(())
    });
}

/// The parallel two-phase pipeline is bit-identical to the sequential
/// decoder for arbitrary bit patterns, kernel geometries, and thread
/// counts — the `seq == parallel` losslessness gate run by CI.
#[test]
fn prop_parallel_equals_sequential() {
    check("df11-seq-parallel-equivalence", cfg(30, 20_000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let t_per_block = [4usize, 8, 64, 256][g.usize_in(0, 3)];
        let n_bytes = [2usize, 4, 8, 16][g.usize_in(0, 3)];
        let config = KernelConfig {
            threads_per_block: t_per_block,
            bytes_per_thread: n_bytes,
            parallelism: 1,
        };
        let t = Df11Tensor::compress_shaped(&ws, &[n], &config).map_err(|e| e.to_string())?;
        let seq = decompress_sequential(&t).map_err(|e| e.to_string())?;
        if seq != ws {
            return Err(format!("sequential mismatch at n={n}"));
        }
        let threads = 1 + g.usize_in(0, 7);
        let par = decompress_parallel(&t, threads).map_err(|e| e.to_string())?;
        if par != seq {
            return Err(format!(
                "parallel != sequential (threads={threads}, T={t_per_block}, n={n_bytes}, len={n})"
            ));
        }
        Ok(())
    });
}

/// Gaussian tensors (realistic exponent skew) roundtrip across random
/// kernel geometries.
#[test]
fn prop_df11_roundtrip_random_geometry() {
    check("df11-geometry", cfg(30, 30_000), |g| {
        let n = g.len().max(8);
        let t_per_block = [4usize, 8, 32, 256][g.usize_in(0, 3)];
        let n_bytes = [2usize, 4, 8, 16][g.usize_in(0, 3)];
        let mut rng = Rng::new(g.rng.next_u64());
        let mut xs = vec![0f32; n];
        rng.fill_gaussian_f32(&mut xs, 0.02);
        let ws: Vec<Bf16> = xs.into_iter().map(Bf16::from_f32).collect();
        let config = KernelConfig {
            threads_per_block: t_per_block,
            bytes_per_thread: n_bytes,
            parallelism: 1 + g.usize_in(0, 2),
        };
        let t = Df11Tensor::compress_shaped(&ws, &[n], &config).map_err(|e| e.to_string())?;
        let mut out = vec![Bf16::from_bits(0); n];
        t.decompress_with(&mut out, &config)
            .map_err(|e| e.to_string())?;
        if out != ws {
            return Err(format!("mismatch T={t_per_block} n={n_bytes} len={n}"));
        }
        Ok(())
    });
}

/// Huffman codebooks from arbitrary frequency tables are prefix-free,
/// Kraft-tight, and decode what they encode.
#[test]
fn prop_huffman_prefix_free_and_roundtrip() {
    check("huffman-prefix-free", cfg(60, 2000), |g| {
        let alphabet = 1 + g.usize_in(0, 255);
        let n = g.len();
        let syms: Vec<u8> = g.vec_of(n, |r| (r.next_index(alphabet)) as u8);
        let mut freqs = [0u64; 256];
        for &s in &syms {
            freqs[s as usize] += 1;
        }
        let cb = Codebook::from_frequencies(&freqs).map_err(|e| e.to_string())?;
        if !is_prefix_free(cb.canonical()) {
            return Err("not prefix free".into());
        }
        if cb.kraft_sum() > 1.0 + 1e-9 {
            return Err(format!("kraft {} > 1", cb.kraft_sum()));
        }
        let (bytes, bits) = encode_symbols(&cb, &syms).map_err(|e| e.to_string())?;
        let back = decode_all(&cb, &bytes, bits).map_err(|e| e.to_string())?;
        if back != syms {
            return Err("decode mismatch".into());
        }
        Ok(())
    });
}

/// The Blelloch scan equals the serial scan for arbitrary inputs.
#[test]
fn prop_blelloch_equals_serial() {
    check("blelloch", cfg(80, 3000), |g| {
        let n = g.usize_in(0, g.size);
        let xs: Vec<u32> = g.vec_of(n, |r| r.next_u32());
        if blelloch_exclusive_scan(&xs) != serial_exclusive_scan(&xs) {
            return Err(format!("scan mismatch at n={n}"));
        }
        Ok(())
    });
}

/// BF16 plane split/merge is the identity for arbitrary bit patterns.
#[test]
fn prop_plane_split_merge_identity() {
    check("planes", cfg(50, 5000), |g| {
        let n = g.len();
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let (e, sm) = split_planes(&ws);
        if merge_planes(&e, &sm) != ws {
            return Err("plane roundtrip broke".into());
        }
        Ok(())
    });
}

/// 5-bit gap packing roundtrips for arbitrary gap arrays.
#[test]
fn prop_gap_packing_roundtrip() {
    check("gap-pack", cfg(60, 4000), |g| {
        let n = g.usize_in(0, g.size);
        let gaps: Vec<u8> = g.vec_of(n, |r| (r.next_index(32)) as u8);
        let packed = pack_gaps(&gaps);
        let back = unpack_gaps(&packed, n).map_err(|e| e.to_string())?;
        if back != gaps {
            return Err("gap roundtrip broke".into());
        }
        Ok(())
    });
}

/// Queue invariants: FIFO order preserved, head always scheduled, no
/// request lost or duplicated under random batch sizes.
#[test]
fn prop_queue_never_starves_or_duplicates() {
    check("queue", cfg(50, 200), |g| {
        let mut q = RequestQueue::new();
        let n = g.usize_in(1, g.size.max(2));
        for i in 0..n {
            q.push(Request::new(vec![i as u32], 1), i as f64)
                .expect("queue-assigned ids");
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            let head = q.queued_ids()[0];
            let batch = q.next_batch(1 + g.usize_in(0, 7));
            if batch.is_empty() {
                return Err("empty batch with non-empty queue".into());
            }
            if batch[0].id != head {
                return Err("head was starved".into());
            }
            seen.extend(batch.into_iter().map(|r| r.id));
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != n || seen.len() != n {
            return Err(format!("lost/duplicated: {} of {n}", seen.len()));
        }
        if !seen.windows(2).all(|w| w[0] < w[1]) {
            return Err("FIFO order violated".into());
        }
        Ok(())
    });
}

/// Compressed size is always within sane bounds: never larger than
/// ~original + overhead, never below the entropy bound.
#[test]
fn prop_compressed_size_bounds() {
    check("size-bounds", cfg(30, 60_000), |g| {
        let n = g.len().max(1000);
        let mut rng = Rng::new(g.rng.next_u64());
        let mut xs = vec![0f32; n];
        rng.fill_gaussian_f32(&mut xs, 0.05);
        let ws: Vec<Bf16> = xs.into_iter().map(Bf16::from_f32).collect();
        let t = Df11Tensor::compress(&ws).map_err(|e| e.to_string())?;
        let entropy = dfloat11::entropy::component_entropy(&ws);
        let lower = (entropy.exponent_bits * n as f64 / 8.0) as u64 + n as u64; // exp + sm planes
        let upper = (n as u64) * 2 + 8192 + n as u64 / 4; // original + overhead
        let c = t.compressed_bytes();
        if c < lower {
            return Err(format!("compressed {c} below entropy bound {lower}"));
        }
        if c > upper {
            return Err(format!("compressed {c} above upper bound {upper}"));
        }
        Ok(())
    });
}

/// Every codec roundtrips bit-exactly through the on-disk container —
/// including mixed-codec containers whose per-tensor codecs are sampled
/// at random and a block picked by the `auto` selector: compress →
/// write container → stream back → decompress equals the source, and a
/// corrupted payload CRC fails with a typed validation error (never a
/// panic).
#[test]
fn prop_container_roundtrip() {
    use dfloat11::codec::all_codecs;
    use dfloat11::codec::select::{CodecSelector, SelectionPolicy};
    use dfloat11::codec::DecodeOpts;
    use dfloat11::container::{ContainerReader, ContainerWriter};
    use dfloat11::error::Error;

    let dir = std::env::temp_dir().join(format!("df11_prop_container_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut case = 0u64;
    check("container-roundtrip", cfg(10, 4000), |g| {
        case += 1;
        let path = dir.join(format!("case_{case}.df11"));
        let n = g.len();
        // Arbitrary bit patterns, NaN/Inf included.
        let ws: Vec<Bf16> = g.vec_of(n, |r| Bf16::from_bits(r.next_u32() as u16));
        let codecs = all_codecs();
        let parts: Vec<_> = codecs
            .iter()
            .map(|c| c.compress(&ws).map(|p| (c.name(), p)))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        // A mixed group whose per-tensor codecs are sampled at random,
        // plus a block picked by the auto selector.
        let mixed: Vec<(String, dfloat11::CompressedTensor)> = (0..3)
            .map(|i| {
                let c = &codecs[g.usize_in(0, codecs.len() - 1)];
                c.compress(&ws).map(|p| (format!("mixed.t{i}"), p))
            })
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        let selector = CodecSelector::new(SelectionPolicy::Auto);
        let (auto_parts, record) = selector
            .select_shaped("auto", "auto.t", &ws, &[n])
            .map_err(|e| e.to_string())?;
        if auto_parts.codec_id() != record.codec {
            return Err("selection record disagrees with the payload codec".into());
        }
        let mut writer = ContainerWriter::new("prop");
        for (name, p) in &parts {
            writer.push(name, name, p.view());
        }
        for (name, p) in &mixed {
            writer.push("mixed", name, p.view());
        }
        writer.push("auto", "auto.t", auto_parts.view());
        let summary = writer.write_to(&path).map_err(|e| e.to_string())?;

        // Roundtrip: stream groups back, decompress, compare bit-exact.
        let reader = ContainerReader::open(&path).map_err(|e| e.to_string())?;
        let threads = 1 + g.usize_in(0, 3);
        for group in reader.groups() {
            let group = group.map_err(|e| e.to_string())?;
            for (name, t) in &group.tensors {
                let back = t
                    .decompress(&DecodeOpts::with_threads(threads))
                    .map_err(|e| e.to_string())?;
                if back != ws {
                    return Err(format!("codec {name} not lossless at n={n}"));
                }
            }
        }
        drop(reader);

        // Corrupt one payload byte: the read must fail with the typed
        // container-validation error, not a panic or silent corruption.
        let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let payload_start = summary.header_bytes as usize;
        let flip = payload_start + g.usize_in(0, (bytes.len() - payload_start).saturating_sub(1));
        bytes[flip] ^= 0x10;
        std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
        let reader = ContainerReader::open(&path).map_err(|e| e.to_string())?;
        let mut failed = false;
        for group in reader.groups() {
            match group {
                Ok(_) => {}
                Err(Error::InvalidContainer(_)) => failed = true,
                Err(other) => return Err(format!("expected validation error, got {other}")),
            }
        }
        if !failed {
            return Err("corrupted payload byte went undetected".into());
        }
        std::fs::remove_file(&path).ok();
        Ok(())
    });
}

/// Stream-level decoder through the multi-symbol fast table, mirroring
/// the production loop in `dfloat11::decompress::decode_stream`:
/// batched multi-symbol lookups, single-symbol fast hits, hierarchical
/// fallback for long codes, and the same overrun-is-an-error bit-budget
/// semantics as [`decode_all`].
fn decode_all_fast(cb: &Codebook, bytes: &[u8], len_bits: u64) -> Result<Vec<u8>, String> {
    let lut = HierarchicalLut::build(cb).map_err(|e| e.to_string())?;
    let fast = FastLut::build(&lut).map_err(|e| e.to_string())?;
    let mut cur = BitCursor::new(bytes, 0);
    let mut out = Vec::new();
    while cur.position() < len_bits {
        cur.refill();
        let e = fast.lookup_multi(cur.window16());
        // Commit a multi-symbol batch only when it fits the bit budget —
        // a partial batch falls through to symbol-at-a-time decode so
        // tail behavior matches the scalar oracle exactly.
        if e != 0 && cur.position() + (e & 0x1F) <= len_bits {
            let count = ((e >> 5) & 0x7) as usize;
            let mut se = e >> 8;
            for _ in 0..count {
                out.push(se as u8);
                se >>= 8;
            }
            cur.consume((e & 0x1F) as u32);
            continue;
        }
        let (symbol, len) = match fast.lookup(cur.window16()) {
            Some(hit) => hit,
            None => lut.lookup(cur.window32()).map_err(|e| e.to_string())?,
        };
        if cur.position() + len as u64 > len_bits {
            return Err(format!("codeword overruns stream at bit {}", cur.position()));
        }
        out.push(symbol);
        cur.consume(len as u32);
    }
    Ok(out)
}

/// Random codebook from one of three shapes: arbitrary skewed
/// frequencies, a Kraft-complete chain forcing max-length (32-bit)
/// codes past every fast-table width, or the degenerate one-symbol
/// book (1-bit code, zero entropy).
fn arb_codebook(g: &mut Gen) -> Codebook {
    match g.usize_in(0, 3) {
        0 => {
            // Chain 1,2,...,31,32,32 — Kraft-complete with L = 32.
            let base = g.usize_in(0, 255);
            let mut lengths = [0u8; 256];
            for i in 0..31 {
                lengths[(base + i) % 256] = (i + 1) as u8;
            }
            lengths[(base + 31) % 256] = 32;
            lengths[(base + 32) % 256] = 32;
            Codebook::from_lengths(&lengths).unwrap()
        }
        1 => {
            let mut freqs = [0u64; 256];
            freqs[g.usize_in(0, 255)] = 1;
            Codebook::from_frequencies(&freqs).unwrap()
        }
        _ => {
            // Exponentially skewed random frequencies: drives a mix of
            // sub-16-bit fast-path codes and long fallback codes.
            let n_syms = g.usize_in(2, 64);
            let mut freqs = [0u64; 256];
            for _ in 0..n_syms {
                let shift = g.usize_in(0, 40);
                freqs[g.usize_in(0, 255)] += 1u64 << shift;
            }
            Codebook::from_frequencies(&freqs).unwrap()
        }
    }
}

/// Symbols actually present in a codebook (code length > 0).
fn present_symbols(cb: &Codebook) -> Vec<u8> {
    (0..=255u8).filter(|&s| cb.lengths()[s as usize] > 0).collect()
}

/// THE fast-path correctness property (satellite of the multi-symbol
/// LUT tentpole): over random codebooks — including max-length 32-bit
/// codes and degenerate one-symbol books — the multi-symbol fast
/// decode, the hierarchical LUT walk, and the scalar oracle produce
/// bit-identical symbol streams for every valid encode.
#[test]
fn prop_fast_hierarchical_scalar_decode_agree() {
    check("fast-hier-scalar-agree", cfg(60, 2_000), |g| {
        let cb = arb_codebook(g);
        let pool = present_symbols(&cb);
        let n = g.len();
        let syms: Vec<u8> = {
            let k = pool.len();
            g.vec_of(n, |r| pool[r.next_index(k)])
        };
        let (bytes, bits) = encode_symbols(&cb, &syms).map_err(|e| e.to_string())?;
        let scalar = decode_all_scalar(cb.canonical(), &bytes, bits).map_err(|e| e.to_string())?;
        let hier = decode_all(&cb, &bytes, bits).map_err(|e| e.to_string())?;
        let fast = decode_all_fast(&cb, &bytes, bits)?;
        if scalar != syms {
            return Err(format!("scalar oracle broke at n={n} (L={})", cb.max_len()));
        }
        if hier != syms {
            return Err(format!("hierarchical decode broke at n={n} (L={})", cb.max_len()));
        }
        if fast != syms {
            return Err(format!("fast-path decode broke at n={n} (L={})", cb.max_len()));
        }
        Ok(())
    });
}

/// Hostile streams (the fuzz corpus's mutation engine over valid
/// encodes, plus pure-random bytes) never make the fast path diverge
/// from the hierarchical walk: both reject with an error or both
/// decode the identical symbol stream. (The scalar oracle is excluded
/// here by design — its length-scan matches codewords through the
/// zero-filled tail, a leniency the production decoders reject.)
#[test]
fn prop_fast_equals_hierarchical_on_hostile_streams() {
    check("fast-hier-hostile-agree", cfg(60, 1_000), |g| {
        let cb = arb_codebook(g);
        let pool = present_symbols(&cb);
        let n = g.len();
        let syms: Vec<u8> = {
            let k = pool.len();
            g.vec_of(n, |r| pool[r.next_index(k)])
        };
        let (mut bytes, bits) = encode_symbols(&cb, &syms).map_err(|e| e.to_string())?;
        // Half the cases mutate a valid encode (bit flips, truncations,
        // splices); half are raw attacker-controlled bytes. The claimed
        // bit length lies in both directions.
        if g.usize_in(0, 1) == 0 {
            let mut m = Mutator::new(g.rng.next_u64());
            m.mutate_n(&mut bytes, 1 + g.usize_in(0, 3));
        } else {
            let blen = g.usize_in(0, 64);
            bytes = g.bytes(blen);
        }
        let max_claim = bytes.len() as u64 * 8 + 40;
        let claimed = if g.usize_in(0, 1) == 0 {
            bits.min(max_claim)
        } else {
            g.usize_in(0, max_claim as usize) as u64
        };
        let hier = decode_all(&cb, &bytes, claimed);
        let fast = decode_all_fast(&cb, &bytes, claimed);
        match (hier, fast) {
            (Ok(h), Ok(f)) => {
                if h != f {
                    return Err(format!(
                        "hostile stream decoded differently: hier {} syms, fast {} syms",
                        h.len(),
                        f.len()
                    ));
                }
            }
            (Err(_), Err(_)) => {}
            (Ok(h), Err(e)) => {
                return Err(format!("fast rejected ({e}) what hier decoded ({} syms)", h.len()));
            }
            (Err(e), Ok(f)) => {
                return Err(format!("hier rejected ({e}) what fast decoded ({} syms)", f.len()));
            }
        }
        Ok(())
    });
}

/// THE decoded-block-cache property (satellite of the cache tentpole):
/// any eviction schedule — random byte capacities from degenerate
/// (nothing fits) through thrash (one block) to all-resident — yields
/// greedy tokens bit-identical to cache-off serving. The cache may
/// only move simulated time, never token content.
#[test]
fn prop_block_cache_eviction_schedule_token_identical() {
    let tokens_by_id = |report: &dfloat11::coordinator::ServeReport| {
        let mut v: Vec<(u64, Vec<u32>)> = report
            .responses
            .iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    check("block-cache-token-identical", cfg(8, 0), |g| {
        let cfg = ModelConfig::test_tiny();
        let vocab = cfg.vocab_size as u32;
        let seed = g.rng.next_u64() % 1000;
        let n_reqs = g.usize_in(1, 4);
        let workload: Vec<Request> = (0..n_reqs)
            .map(|_| {
                let plen = g.usize_in(1, 4);
                let prompt = g.vec_of(plen, |r| r.next_u32() % vocab);
                Request::new(prompt, g.usize_in(1, 5))
            })
            .collect();
        // 1 KiB starves every block; tens of MiB holds the whole tiny
        // model; the middle of the range forces LRU eviction churn.
        let capacity = 1u64 << g.usize_in(10, 25);
        let run = |mode: BlockCacheMode| -> Result<_, String> {
            let engine = Engine::build(&cfg, seed, WeightMode::Df11).map_err(|e| e.to_string())?;
            let mut server = Server::new(
                engine,
                SchedulerConfig {
                    max_batch: 2,
                    block_cache: mode,
                    ..SchedulerConfig::default()
                },
            );
            for r in &workload {
                server.submit(r.clone()).map_err(|e| e.to_string())?;
            }
            server.drain().map_err(|e| e.to_string())
        };
        let off = run(BlockCacheMode::Off)?;
        let on = run(BlockCacheMode::Bytes(capacity))?;
        if off.block_cache.is_some() {
            return Err("cache-off run reported cache stats".into());
        }
        let stats = on
            .block_cache
            .ok_or_else(|| "cache-on run reported no cache stats".to_string())?;
        if stats.hits + stats.misses == 0 {
            return Err("cache-on run never consulted the cache".into());
        }
        if tokens_by_id(&off) != tokens_by_id(&on) {
            return Err(format!(
                "token divergence at capacity {capacity} ({} hits, {} evictions)",
                stats.hits, stats.evictions
            ));
        }
        Ok(())
    });
}

/// rANS roundtrips arbitrary byte streams.
#[test]
fn prop_rans_roundtrip() {
    check("rans", cfg(40, 10_000), |g| {
        let n = g.len();
        let skew = g.usize_in(1, 8);
        let data: Vec<u8> = g.vec_of(n, |r| (r.next_index(1 << skew)) as u8);
        let model = dfloat11::ans::RansModel::from_data(&data);
        let enc = dfloat11::ans::rans_encode(&model, &data).map_err(|e| e.to_string())?;
        let dec =
            dfloat11::ans::rans_decode(&model, &enc, data.len()).map_err(|e| e.to_string())?;
        if dec != data {
            return Err("rans roundtrip broke".into());
        }
        Ok(())
    });
}
