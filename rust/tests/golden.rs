//! Golden-fixture losslessness: a tiny `.df11` container is checked in
//! at `tests/fixtures/golden.df11` together with the pinned CRC-32 of
//! its fully decoded weights. Every codec path — container range
//! reads, the sequential DF11 decoder, the parallel two-phase
//! pipeline, and the rANS baseline — must reproduce exactly that CRC,
//! so silent on-disk or decoder format drift across PRs fails loudly
//! here instead of corrupting weights quietly.
//!
//! The fixture's weights are integer-deterministic (a fixed LCG over
//! safe BF16 bit patterns, no floats involved), so the file is
//! reproducible byte-for-byte: `fixture_matches_canonical_writer_output`
//! rebuilds it through `ContainerWriter` and compares bytes.

use dfloat11::bf16::Bf16;
use dfloat11::codec::select::{CodecSelector, SelectionPolicy};
use dfloat11::codec::{Codec, DecodeOpts, RansCodec, SplitStreamCodec};
use dfloat11::container::{ContainerReader, ContainerWriter, CONTAINER_VERSION};
use dfloat11::coordinator::{BlockCacheMode, Engine, Request, SchedulerConfig, Server};
use dfloat11::crc32::Hasher;
use dfloat11::dfloat11::decompress::{
    decompress_sequential, decompress_sequential_hierarchical_into,
};
use dfloat11::Df11Tensor;
use dfloat11::IoBackend;
use std::path::PathBuf;

/// CRC-32 over the concatenated BF16 bits (little-endian) of every
/// tensor in index order. Pinned: changing it means the format or a
/// decoder changed behavior.
const GOLDEN_WEIGHTS_CRC32: u32 = 0x5fa90c47;

/// The fixture inventory: (group, name, shape, LCG seed).
const GOLDEN_TENSORS: [(&str, &str, &[usize], u32); 5] = [
    ("embed", "embed.tok", &[32, 16], 1),
    ("block.0", "block.0.w", &[24, 24], 2),
    ("block.0", "block.0.v", &[600], 3),
    ("block.1", "block.1.w", &[24, 24], 4),
    ("lm_head", "lm_head", &[16, 32], 5),
];
const GOLDEN_MODEL_NAME: &str = "golden-fixture";

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden.df11")
}

/// LCG step → a finite, normal BF16 bit pattern (exponent 120..135:
/// no NaN/Inf/subnormal edge cases in the golden weights).
fn golden_bits(state: &mut u32) -> u16 {
    *state = state.wrapping_mul(1664525).wrapping_add(1013904223);
    let s = *state;
    let sign = ((s >> 31) & 1) as u16;
    let exp = (120 + ((s >> 23) & 0x0F)) as u16;
    let man = ((s >> 9) & 0x7F) as u16;
    (sign << 15) | (exp << 7) | man
}

fn golden_weights(shape: &[usize], seed: u32) -> Vec<Bf16> {
    let n: usize = shape.iter().product();
    let mut state = seed;
    (0..n).map(|_| Bf16::from_bits(golden_bits(&mut state))).collect()
}

/// CRC-32 over tensors' bits in the given order.
fn crc_of(tensors: &[Vec<Bf16>]) -> u32 {
    let mut h = Hasher::new();
    for t in tensors {
        for w in t {
            h.update(&w.to_bits().to_le_bytes());
        }
    }
    h.finalize()
}

#[test]
fn generator_reproduces_the_pinned_crc() {
    // The in-test generator itself must match the pinned CRC — if this
    // fails, the constant and the fixture were regenerated out of sync.
    let tensors: Vec<Vec<Bf16>> = GOLDEN_TENSORS
        .iter()
        .map(|&(_, _, shape, seed)| golden_weights(shape, seed))
        .collect();
    assert_eq!(crc_of(&tensors), GOLDEN_WEIGHTS_CRC32);
}

#[test]
fn golden_fixture_decodes_to_pinned_crc() {
    let reader = ContainerReader::open(&fixture_path()).expect("checked-in fixture opens");
    assert_eq!(reader.model_name(), GOLDEN_MODEL_NAME);
    assert_eq!(reader.version(), CONTAINER_VERSION);
    assert_eq!(reader.entries().len(), GOLDEN_TENSORS.len());

    let mut decoded = Vec::new();
    for (i, &(group, name, shape, seed)) in GOLDEN_TENSORS.iter().enumerate() {
        let entry = &reader.entries()[i];
        assert_eq!(entry.group, group);
        assert_eq!(entry.name, name);
        assert_eq!(entry.shape, shape.to_vec());
        let w = reader
            .read_tensor_at(i)
            .unwrap()
            .decompress(&DecodeOpts::default())
            .unwrap();
        // Range-read output matches the regenerated source bitwise.
        assert_eq!(w, golden_weights(shape, seed), "tensor {name}");
        decoded.push(w);
    }
    assert_eq!(
        crc_of(&decoded),
        GOLDEN_WEIGHTS_CRC32,
        "container range-read path drifted"
    );
}

#[test]
fn golden_weights_survive_every_codec_path() {
    let source: Vec<Vec<Bf16>> = GOLDEN_TENSORS
        .iter()
        .map(|&(_, _, shape, seed)| golden_weights(shape, seed))
        .collect();

    // DF11 sequential decoder.
    let df11: Vec<Df11Tensor> = source
        .iter()
        .map(|w| Df11Tensor::compress(w).unwrap())
        .collect();
    let serial: Vec<Vec<Bf16>> = df11.iter().map(|t| t.decompress().unwrap()).collect();
    assert_eq!(crc_of(&serial), GOLDEN_WEIGHTS_CRC32, "df11 serial path");

    // The multi-symbol fast path and the forced hierarchical fallback
    // pin the same CRC: the fast table is an optimization, never a
    // format change.
    let fast: Vec<Vec<Bf16>> = df11
        .iter()
        .map(|t| decompress_sequential(t).unwrap())
        .collect();
    assert_eq!(crc_of(&fast), GOLDEN_WEIGHTS_CRC32, "df11 fast-path serial");
    let hier: Vec<Vec<Bf16>> = df11
        .iter()
        .map(|t| {
            let mut out = vec![Bf16::from_bits(0); t.num_elements()];
            decompress_sequential_hierarchical_into(t, &mut out).unwrap();
            out
        })
        .collect();
    assert_eq!(
        crc_of(&hier),
        GOLDEN_WEIGHTS_CRC32,
        "df11 hierarchical fallback path"
    );

    // DF11 parallel two-phase pipeline (explicit pool width, no
    // small-tensor dispatch shortcut).
    let parallel: Vec<Vec<Bf16>> = df11
        .iter()
        .map(|t| t.decompress_parallel(4).unwrap())
        .collect();
    assert_eq!(crc_of(&parallel), GOLDEN_WEIGHTS_CRC32, "df11 parallel path");

    // The same pipeline through explicit persistent pools: every
    // width × stealing configuration reproduces the pinned CRC (work
    // stealing may move *where* a stripe decodes, never a bit of it).
    for width in [1usize, 2, 8] {
        for stealing in [true, false] {
            let pool = dfloat11::WorkerPool::with_config(width, stealing);
            let pooled: Vec<Vec<Bf16>> = df11
                .iter()
                .map(|t| {
                    let mut out = vec![Bf16::from_bits(0); t.num_elements()];
                    dfloat11::dfloat11::parallel::decompress_pooled_into(
                        t, &mut out, width, &pool,
                    )
                    .unwrap();
                    out
                })
                .collect();
            assert_eq!(
                crc_of(&pooled),
                GOLDEN_WEIGHTS_CRC32,
                "pooled path width={width} stealing={stealing}"
            );
        }
    }

    // rANS baseline codec.
    let rans: Vec<Vec<Bf16>> = source
        .iter()
        .map(|w| {
            RansCodec
                .compress(w)
                .unwrap()
                .decompress(&DecodeOpts::default())
                .unwrap()
        })
        .collect();
    assert_eq!(crc_of(&rans), GOLDEN_WEIGHTS_CRC32, "rans path");

    // Split-stream codec (packed planes + Huffman exponents).
    let split: Vec<Vec<Bf16>> = source
        .iter()
        .map(|w| {
            SplitStreamCodec::default()
                .compress(w)
                .unwrap()
                .decompress(&DecodeOpts::default())
                .unwrap()
        })
        .collect();
    assert_eq!(crc_of(&split), GOLDEN_WEIGHTS_CRC32, "split-stream path");

    // DF11 payloads through a container: write, then range-read back
    // in scrambled order.
    let dir = std::env::temp_dir().join("df11_golden_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("roundtrip_{}.df11", std::process::id()));
    let mut writer = ContainerWriter::new(GOLDEN_MODEL_NAME);
    for (&(group, name, _, _), t) in GOLDEN_TENSORS.iter().zip(&df11) {
        writer.push(group, name, dfloat11::codec::CompressedRef::Df11(t));
    }
    writer.write_to(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let mut by_index: Vec<Vec<Bf16>> = vec![Vec::new(); GOLDEN_TENSORS.len()];
    for i in (0..GOLDEN_TENSORS.len()).rev() {
        by_index[i] = reader
            .read_tensor_at(i)
            .unwrap()
            .decompress(&DecodeOpts::with_threads(2))
            .unwrap();
    }
    assert_eq!(
        crc_of(&by_index),
        GOLDEN_WEIGHTS_CRC32,
        "df11 container range-read path"
    );
    std::fs::remove_file(&path).ok();

    // Auto-selected payloads through a container: each tensor carries
    // its per-tensor winning codec, and the mixed-codec container must
    // still decode to the pinned CRC.
    let selector = CodecSelector::new(SelectionPolicy::Auto);
    let mut writer = ContainerWriter::new(GOLDEN_MODEL_NAME);
    for (&(group, name, shape, _), w) in GOLDEN_TENSORS.iter().zip(&source) {
        let (t, record) = selector.select_shaped(group, name, w, shape).unwrap();
        assert_eq!(t.codec_id(), record.codec, "record tracks the payload");
        writer.push(group, name, t.view());
    }
    let path = dir.join(format!("auto_{}.df11", std::process::id()));
    writer.write_to(&path).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    let auto_decoded: Vec<Vec<Bf16>> = (0..GOLDEN_TENSORS.len())
        .map(|i| {
            reader
                .read_tensor_at(i)
                .unwrap()
                .decompress(&DecodeOpts::with_threads(2))
                .unwrap()
        })
        .collect();
    assert_eq!(
        crc_of(&auto_decoded),
        GOLDEN_WEIGHTS_CRC32,
        "auto mixed-codec container path"
    );
    std::fs::remove_file(&path).ok();
}

/// Serving losslessness through the decoded-block cache: a
/// container-backed engine on every `--io` backend, with the cache off,
/// generously sized, and squeezed into eviction churn, must emit one
/// identical token digest — and the warm cache must actually hit.
#[test]
fn golden_serving_tokens_identical_cache_on_off_across_io_backends() {
    use dfloat11::dfloat11::Df11Model;
    use dfloat11::model::init::generate_model_weights;
    use dfloat11::model::ModelConfig;

    let cfg = ModelConfig::test_tiny();
    let raw = generate_model_weights(&cfg, 41);
    let model = Df11Model::compress_from_weights(cfg.name.clone(), raw).unwrap();
    let dir = std::env::temp_dir().join("df11_golden_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("serve_cache_{}.df11", std::process::id()));
    dfloat11::container::write_df11_model(&path, &model).unwrap();

    let workload: Vec<Request> = (0..4)
        .map(|i| Request::new(vec![(i * 13 % 40 + 1) as u32, 3, 9], 3 + i % 3))
        .collect();

    // Token digest in request-id order, like the CLI's `tokens-crc32`.
    let token_crc = |report: &dfloat11::coordinator::ServeReport| {
        let mut responses: Vec<_> = report.responses.iter().collect();
        responses.sort_by_key(|r| r.id);
        let mut h = Hasher::new();
        for r in &responses {
            h.update(&r.id.to_le_bytes());
            for t in &r.tokens {
                h.update(&t.to_le_bytes());
            }
        }
        h.finalize()
    };

    let run = |io: IoBackend, cache: BlockCacheMode| {
        let engine = Engine::build_from_container_with(&cfg, &path, io).unwrap();
        let mut server = Server::new(
            engine,
            SchedulerConfig {
                max_batch: 2,
                block_cache: cache,
                ..SchedulerConfig::default()
            },
        );
        for r in &workload {
            server.submit(r.clone()).unwrap();
        }
        server.drain().unwrap()
    };

    let baseline = run(IoBackend::Read, BlockCacheMode::Off);
    assert!(baseline.block_cache.is_none());
    let pinned = token_crc(&baseline);

    for io in IoBackend::ALL {
        for cache in [
            BlockCacheMode::Off,
            BlockCacheMode::Bytes(1 << 30), // everything fits: pure hits after warmup
            BlockCacheMode::Bytes(16 << 10), // eviction churn
        ] {
            let report = run(io, cache);
            assert_eq!(
                report.responses.len(),
                workload.len(),
                "{io} cache={cache:?} lost responses"
            );
            assert_eq!(
                token_crc(&report),
                pinned,
                "{io} cache={cache:?} drifted from the cache-off token digest"
            );
            if let BlockCacheMode::Bytes(cap) = cache {
                let stats = report.block_cache.expect("cache-on run reports stats");
                assert_eq!(stats.capacity, cap);
                assert!(
                    stats.hits + stats.misses > 0,
                    "{io} cache={cache:?} never consulted the cache"
                );
                if cap == 1 << 30 {
                    assert!(
                        stats.hits > 0,
                        "{io}: a generously sized warm cache must hit"
                    );
                    assert_eq!(stats.evictions, 0, "{io}: nothing to evict at 1 GiB");
                }
            } else {
                assert!(report.block_cache.is_none());
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn fixture_matches_canonical_writer_output() {
    // Rebuild the fixture through `ContainerWriter` (raw-bf16 payloads,
    // same order) and require byte identity with the checked-in file —
    // any writer-format drift shows up as a diff here, and the fixture
    // can be regenerated by writing this test's output over it.
    let tensors: Vec<_> = GOLDEN_TENSORS
        .iter()
        .map(|&(_, _, shape, seed)| {
            dfloat11::codec::RawBf16Codec
                .compress_shaped(&golden_weights(shape, seed), shape)
                .unwrap()
        })
        .collect();
    let mut writer = ContainerWriter::new(GOLDEN_MODEL_NAME);
    for (&(group, name, _, _), t) in GOLDEN_TENSORS.iter().zip(&tensors) {
        writer.push(group, name, t.view());
    }
    let dir = std::env::temp_dir().join("df11_golden_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("canonical_{}.df11", std::process::id()));
    writer.write_to(&path).unwrap();
    let rebuilt = std::fs::read(&path).unwrap();
    let committed = std::fs::read(fixture_path()).unwrap();
    assert_eq!(
        rebuilt, committed,
        "writer output no longer matches the checked-in golden fixture"
    );
    std::fs::remove_file(&path).ok();
}
