//! Fleet-scale replicated serving, end to end.
//!
//! The fleet must preserve the paper's losslessness guarantee across
//! every deployment shape: tokens are bit-identical whether a request
//! is served by one box or routed across N replicas by any
//! `RouterPolicy`, from BF16, DF11, or container-backed weights — even
//! when a replica dies mid-flight and its work is re-routed.

use dfloat11::container::write_df11_model;
use dfloat11::coordinator::{
    Engine, Fleet, FleetReport, LeastLoaded, RejectReason, ReplicaHealth, Request, RoundRobin,
    RouterPolicy, ServeConfig, SessionAffinity, SubmitOutcome, WeightMode,
};
use dfloat11::dfloat11::Df11Model;
use dfloat11::error::Error;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::ModelConfig;
use dfloat11::proptest_lite::{check, Config};
use std::path::PathBuf;

fn tiny() -> ModelConfig {
    ModelConfig::test_tiny()
}

enum Source {
    Bf16,
    Df11,
    Container(PathBuf),
}

fn build_engine(cfg: &ModelConfig, seed: u64, src: &Source) -> Engine {
    match src {
        Source::Bf16 => Engine::build(cfg, seed, WeightMode::Bf16Resident).unwrap(),
        Source::Df11 => Engine::build(cfg, seed, WeightMode::Df11).unwrap(),
        Source::Container(path) => Engine::build_from_container(cfg, path).unwrap(),
    }
}

fn router_by(name: &str) -> Box<dyn RouterPolicy> {
    match name {
        "rr" => Box::new(RoundRobin::new()),
        "least-loaded" => Box::new(LeastLoaded::new()),
        "session" => Box::new(SessionAffinity::new()),
        other => panic!("unknown router {other}"),
    }
}

/// Deterministic mixed workload; `sessions > 0` stamps session keys so
/// the sticky router has something to pin.
fn workload(n: usize, sessions: u64) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt: Vec<u32> = (0..3).map(|t| ((i * 13 + t * 5) % 50 + 1) as u32).collect();
            let mut r = Request::new(prompt, 2 + i % 3);
            if sessions > 0 {
                r = r.with_session(i as u64 % sessions);
            }
            r
        })
        .collect()
}

fn run_fleet(
    cfg: &ModelConfig,
    seed: u64,
    src: &Source,
    n: usize,
    router: &str,
    config: ServeConfig,
    workload: &[Request],
) -> FleetReport {
    let engines: Vec<Engine> = (0..n).map(|_| build_engine(cfg, seed, src)).collect();
    let mut fleet = Fleet::new(engines, config.replicas(n), router_by(router)).unwrap();
    for r in workload {
        let at = r.arrival;
        fleet.submit_at(r.clone(), at).unwrap();
    }
    fleet.drain().unwrap()
}

/// Tokens per request id, for order-independent comparison.
fn tokens_by_id(report: &FleetReport) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<(u64, Vec<u32>)> = report
        .responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

/// THE fleet-losslessness matrix: replica counts {1, 2, 4} x weight
/// sources {bf16, df11, container} x all three router policies emit
/// tokens bit-identical to a single BF16 replica.
#[test]
fn fleet_tokens_bit_identical_across_replica_counts_sources_and_routers() {
    let cfg = tiny();
    let seed = 13;
    let work = workload(6, 3);

    // Container-backed replicas read the same weights from disk.
    let raw = generate_model_weights(&cfg, seed);
    let model = Df11Model::compress_from_weights(cfg.name.clone(), raw).unwrap();
    let dir = std::env::temp_dir().join("df11_fleet_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("fleet_{}.df11", std::process::id()));
    write_df11_model(&path, &model).unwrap();

    let reference = tokens_by_id(&run_fleet(
        &cfg,
        seed,
        &Source::Bf16,
        1,
        "rr",
        ServeConfig::new().slots(2),
        &work,
    ));
    assert_eq!(reference.len(), 6);

    for src in [Source::Bf16, Source::Df11, Source::Container(path.clone())] {
        for n in [1usize, 2, 4] {
            for router in ["rr", "least-loaded", "session"] {
                let report =
                    run_fleet(&cfg, seed, &src, n, router, ServeConfig::new().slots(2), &work);
                assert!(report.rejections.is_empty());
                assert_eq!(
                    tokens_by_id(&report),
                    reference,
                    "{n} replicas, router {router}"
                );
                // Every admission went to a live replica in range.
                assert!(report.routes.iter().all(|r| r.replica < n));
            }
        }
    }
    std::fs::remove_file(&path).ok();

    // Static admission through the fleet agrees too.
    let report = run_fleet(
        &cfg,
        seed,
        &Source::Df11,
        2,
        "rr",
        ServeConfig::new().static_batch().slots(2),
        &work,
    );
    assert_eq!(tokens_by_id(&report), reference, "static fleet");
}

/// Session-affinity stickiness property: with every replica healthy
/// and slots to spare, all requests sharing a session key land on one
/// replica — the key's stable preferred replica.
#[test]
fn prop_session_affinity_is_sticky() {
    let cfg = tiny();
    check(
        "session-stickiness",
        Config {
            cases: 6,
            max_size: 32,
            ..Config::default()
        },
        |g| {
            let n = g.usize_in(1, 4);
            let sessions = g.usize_in(1, 4) as u64;
            let n_reqs = g.usize_in(4, 8);
            // Ample slots: the preferred replica is always a candidate.
            let config = ServeConfig::new().slots(n_reqs);
            let work = workload(n_reqs, sessions);
            let report = run_fleet(&cfg, 7, &Source::Bf16, n, "session", config, &work);
            if report.responses.len() != n_reqs {
                return Err("lost responses".into());
            }
            // Ids are queue-assigned in submit order: request i -> id i+1,
            // session i % sessions.
            for route in &report.routes {
                let session = (route.request_id - 1) % sessions;
                let want = SessionAffinity::preferred(session, n);
                if route.replica != want {
                    return Err(format!(
                        "session {session} routed to replica {} (preferred {want}) \
                         with {n} replicas",
                        route.replica
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Routing never targets a dead replica: mark one dead up front and
/// every admission must land elsewhere, with all work completing.
#[test]
fn least_loaded_never_routes_to_dead_replica() {
    let cfg = tiny();
    let work = workload(9, 0);
    let engines: Vec<Engine> = (0..3)
        .map(|_| Engine::build(&cfg, 3, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(2).replicas(3),
        Box::new(LeastLoaded::new()),
    )
    .unwrap();
    fleet.set_health(1, ReplicaHealth::Dead).unwrap();
    assert_eq!(fleet.replica_health(1), Some(ReplicaHealth::Dead));
    for r in &work {
        fleet.submit(r.clone()).unwrap();
    }
    let report = fleet.drain().unwrap();
    assert_eq!(report.responses.len(), 9);
    assert!(!report.routes.is_empty());
    assert!(
        report.routes.iter().all(|r| r.replica != 1),
        "no admission may target the dead replica"
    );
    // A draining replica is also never routed to.
    let engines: Vec<Engine> = (0..2)
        .map(|_| Engine::build(&cfg, 3, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(2).replicas(2),
        Box::new(LeastLoaded::new()),
    )
    .unwrap();
    fleet.set_health(0, ReplicaHealth::Draining).unwrap();
    for r in &work {
        fleet.submit(r.clone()).unwrap();
    }
    let report = fleet.drain().unwrap();
    assert_eq!(report.responses.len(), 9);
    assert!(report.routes.iter().all(|r| r.replica == 1));
}

/// Backpressure is a typed outcome on both submit paths: closed-loop
/// submits past the bound reject at the door, and open-loop arrivals
/// past the bound reject during the drain — never a panic, and the
/// accepted work still completes.
#[test]
fn bounded_queue_rejects_with_typed_outcome() {
    let cfg = tiny();
    let config = ServeConfig::new().slots(1).replicas(2).queue_capacity(2);
    let engines: Vec<Engine> = (0..2)
        .map(|_| Engine::build(&cfg, 5, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(engines, config, Box::new(RoundRobin::new())).unwrap();

    // Closed loop: 4 submits now against a capacity of 2.
    let mut door_rejects = 0;
    for r in workload(4, 0) {
        match fleet.submit(r).unwrap() {
            SubmitOutcome::Enqueued(id) => assert!(id > 0),
            SubmitOutcome::Rejected(rej) => {
                assert_eq!(rej.reason, RejectReason::QueueFull);
                door_rejects += 1;
            }
            SubmitOutcome::Deferred => panic!("now-arrivals are not deferred"),
        }
    }
    assert_eq!(door_rejects, 2, "capacity 2 admits 2 of 4 immediate submits");

    // Open loop: 4 more arriving together later; the queue is drained
    // by then but still only holds 2.
    for r in workload(4, 0) {
        assert_eq!(
            fleet.submit_at(r, 1e6).unwrap(),
            SubmitOutcome::Deferred,
            "future arrivals park until the clock reaches them"
        );
    }
    let report = fleet.drain().unwrap();
    assert_eq!(
        report.responses.len() + report.rejections.len(),
        8,
        "every offered request is accounted for"
    );
    assert_eq!(report.responses.len(), 4);
    assert_eq!(report.rejections.len(), 4);
    assert!(report
        .rejections
        .iter()
        .all(|r| r.reason == RejectReason::QueueFull));
}

/// With every replica dead, accepted work is rejected with a typed
/// reason instead of wedging the drain loop.
#[test]
fn all_replicas_dead_rejects_gracefully() {
    let cfg = tiny();
    let engines: Vec<Engine> = (0..2)
        .map(|_| Engine::build(&cfg, 5, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(2).replicas(2),
        Box::new(RoundRobin::new()),
    )
    .unwrap();
    fleet.set_health(0, ReplicaHealth::Dead).unwrap();
    fleet.set_health(1, ReplicaHealth::Dead).unwrap();
    for r in workload(3, 0) {
        fleet.submit(r).unwrap();
    }
    let report = fleet.drain().unwrap();
    assert!(report.responses.is_empty());
    assert_eq!(report.rejections.len(), 3);
    assert!(report
        .rejections
        .iter()
        .all(|r| r.reason == RejectReason::NoHealthyReplica));
    // Dead replicas cannot rejoin.
    assert!(matches!(
        fleet.set_health(0, ReplicaHealth::Healthy),
        Err(Error::Scheduler(_))
    ));
}

/// A request whose worst-case KV demand exceeds every replica's whole
/// budget is rejected as unschedulable (the single-server path returns
/// a typed error; the fleet keeps serving everyone else).
#[test]
fn oversized_request_is_rejected_unschedulable() {
    let cfg = tiny();
    let page_tokens = 16u64;
    let resident = Engine::build(&cfg, 5, WeightMode::Bf16Resident)
        .unwrap()
        .resident_weight_bytes();
    // Budget leaves exactly one 16-token KV page per replica.
    let budget = resident + page_tokens * cfg.kv_bytes_per_token();
    let config = ServeConfig::new()
        .slots(2)
        .replicas(2)
        .hbm_budget(budget)
        .page_tokens(page_tokens);
    let engines: Vec<Engine> = (0..2)
        .map(|_| Engine::build(&cfg, 5, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(engines, config, Box::new(LeastLoaded::new())).unwrap();
    // Fits: 2 prompt + 4 new - 1 = 5 worst-case tokens -> 1 page.
    fleet.submit(Request::new(vec![1, 2], 4)).unwrap();
    // Can never fit: worst case 21 tokens -> 2 pages > 1 total.
    fleet.submit(Request::new(vec![3, 4], 19)).unwrap();
    let report = fleet.drain().unwrap();
    assert_eq!(report.responses.len(), 1);
    assert_eq!(report.rejections.len(), 1);
    assert_eq!(report.rejections[0].reason, RejectReason::Unschedulable);
    assert_eq!(report.rejections[0].id, 2);
}

/// Replica-death regression: killing a replica mid-run re-routes its
/// in-flight work under the *original* queue-assigned ids — every id
/// appears in exactly one response, and the tokens are bit-identical
/// to an undisturbed fleet (regeneration restarts from the prompt).
#[test]
fn replica_death_reroutes_without_duplicate_responses() {
    let cfg = tiny();
    let work = workload(8, 0);
    let reference = tokens_by_id(&run_fleet(
        &cfg,
        21,
        &Source::Bf16,
        2,
        "rr",
        ServeConfig::new().slots(4),
        &work,
    ));

    let engines: Vec<Engine> = (0..2)
        .map(|_| Engine::build(&cfg, 21, WeightMode::Bf16Resident).unwrap())
        .collect();
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(4).replicas(2),
        Box::new(RoundRobin::new()),
    )
    .unwrap();
    // Fires at the first loop turn after the first decode tick (any
    // real tick advances the clock past 1e-12), while all 8 requests
    // are still in flight: 4 on each replica.
    fleet.kill_at(0, 1e-12).unwrap();
    for r in &work {
        fleet.submit(r.clone()).unwrap();
    }
    let report = fleet.drain().unwrap();

    assert_eq!(report.responses.len(), 8, "no request is lost");
    let mut ids: Vec<u64> = report.responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (1..=8).collect::<Vec<u64>>(), "each id answers once");
    assert_eq!(tokens_by_id(&report), reference, "re-route is lossless");

    assert_eq!(report.health_events.len(), 1);
    let death = &report.health_events[0];
    assert_eq!(death.replica, 0);
    assert_eq!(death.health, ReplicaHealth::Dead);
    assert_eq!(death.rerouted, 4, "replica 0 held half the fleet's work");
    let reroutes = report.routes.iter().filter(|r| r.reroute).count();
    assert_eq!(reroutes, 4, "each re-queued request is re-admitted once");
    assert!(report
        .routes
        .iter()
        .filter(|r| r.reroute)
        .all(|r| r.replica == 1));
    assert_eq!(report.per_replica[0].health, ReplicaHealth::Dead);
    // Completed-token accounting lands on the surviving replica.
    assert_eq!(report.per_replica[1].tokens, report.total_tokens);
}

/// Ids stay queue-owned across every fleet submit path.
#[test]
fn fleet_rejects_preset_ids() {
    let cfg = tiny();
    let engines = vec![Engine::build(&cfg, 5, WeightMode::Bf16Resident).unwrap()];
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().replicas(1),
        Box::new(RoundRobin::new()),
    )
    .unwrap();
    let mut r = Request::new(vec![1], 1);
    r.id = 7;
    assert!(fleet.submit(r.clone()).is_err());
    assert!(fleet.submit_at(r, 2.0).is_err(), "deferred path checks too");
    // Config mismatches are typed Config errors.
    let engines = vec![Engine::build(&cfg, 5, WeightMode::Bf16Resident).unwrap()];
    assert!(matches!(
        Fleet::new(
            engines,
            ServeConfig::new().replicas(2),
            Box::new(RoundRobin::new())
        ),
        Err(Error::Config(_))
    ));
}
