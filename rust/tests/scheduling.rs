//! Scheduler equivalence and continuous-batching behavior, end to end.
//!
//! The redesigned serving API must preserve the paper's core guarantee
//! (losslessness: every weight source emits identical greedy tokens)
//! while changing *when* work happens: continuous scheduling admits
//! mid-flight and must never perturb any request's tokens, only its
//! latency.

use dfloat11::coordinator::{
    Engine, FinishReason, Request, SchedPolicy, SchedulerConfig, Server, WeightMode,
};
use dfloat11::dfloat11::Df11Model;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::ModelConfig;
use dfloat11::proptest_lite::{check, Config};

fn tiny() -> ModelConfig {
    ModelConfig::test_tiny()
}

fn cfg(cases: u32, max_size: usize) -> Config {
    Config {
        cases,
        max_size,
        ..Config::default()
    }
}

fn serve(
    policy: SchedPolicy,
    slots: usize,
    mode: WeightMode,
    seed: u64,
    workload: &[Request],
) -> dfloat11::coordinator::ServeReport {
    let engine = Engine::build(&tiny(), seed, mode).unwrap();
    let mut server = Server::new(
        engine,
        SchedulerConfig {
            max_batch: slots,
            policy,
            ..SchedulerConfig::default()
        },
    );
    for r in workload {
        let at = r.arrival;
        server.submit_at(r.clone(), at).unwrap();
    }
    server.drain().unwrap()
}

/// Tokens per request id, for order-independent comparison.
fn tokens_by_id(report: &dfloat11::coordinator::ServeReport) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<(u64, Vec<u32>)> = report
        .responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

/// THE scheduler-equivalence property: continuous and static
/// scheduling emit identical greedy tokens for every request — random
/// mixed-length prompts, random per-request budgets, random slot
/// counts. Only latency may differ.
#[test]
fn prop_continuous_matches_static_tokenwise() {
    check("sched-equivalence", cfg(12, 48), |g| {
        let n_reqs = g.usize_in(1, 6);
        let slots = g.usize_in(1, 4);
        let vocab = tiny().vocab_size as u32;
        let workload: Vec<Request> = (0..n_reqs)
            .map(|_| {
                let plen = g.usize_in(1, 5);
                let prompt = g.vec_of(plen, |r| r.next_u32() % vocab);
                Request::new(prompt, g.usize_in(1, 6))
            })
            .collect();
        let stat = serve(SchedPolicy::Static, slots, WeightMode::Bf16Resident, 9, &workload);
        let cont = serve(
            SchedPolicy::Continuous,
            slots,
            WeightMode::Bf16Resident,
            9,
            &workload,
        );
        if stat.responses.len() != n_reqs || cont.responses.len() != n_reqs {
            return Err("lost responses".into());
        }
        if tokens_by_id(&stat) != tokens_by_id(&cont) {
            return Err(format!(
                "token divergence with {n_reqs} requests on {slots} slots"
            ));
        }
        Ok(())
    });
}

/// Bf16, Df11, and container-backed sources agree tokenwise under
/// continuous batching (losslessness through the redesigned scheduler).
#[test]
fn sources_agree_tokenwise_under_continuous_batching() {
    let cfg = tiny();
    let seed = 13;
    let workload: Vec<Request> = (0..5)
        .map(|i| Request::new(vec![(i * 11 % 50 + 1) as u32, 7, 8], 3 + i % 4))
        .collect();

    let run = |engine: Engine| {
        let mut server = Server::new(engine, SchedulerConfig::continuous(2));
        for r in &workload {
            server.submit(r.clone()).unwrap();
        }
        tokens_by_id(&server.drain().unwrap())
    };

    let bf16 = run(Engine::build(&cfg, seed, WeightMode::Bf16Resident).unwrap());
    let df11 = run(Engine::build(&cfg, seed, WeightMode::Df11).unwrap());
    assert_eq!(bf16, df11, "df11 == bf16 under continuous batching");

    // Container-backed serving: same weights from disk.
    let raw = generate_model_weights(&cfg, seed);
    let model = Df11Model::compress_from_weights(cfg.name.clone(), raw).unwrap();
    let dir = std::env::temp_dir().join("df11_scheduling_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("sched_{}.df11", std::process::id()));
    dfloat11::container::write_df11_model(&path, &model).unwrap();
    let container = run(Engine::build_from_container(&cfg, &path).unwrap());
    std::fs::remove_file(&path).ok();
    assert_eq!(bf16, container, "container == bf16 under continuous batching");
}

/// A workload with one head-of-line long request and a tail of short
/// ones: continuous scheduling backfills the freed slot immediately,
/// so its mean queue delay and mean TTFT are strictly lower than
/// static round-based scheduling at the same slot count.
#[test]
fn continuous_beats_static_on_queue_delay_and_ttft() {
    let mut workload = vec![Request::new(vec![1, 2], 16)];
    for i in 0..6 {
        workload.push(Request::new(vec![i as u32 + 3], 1));
    }
    let stat = serve(SchedPolicy::Static, 2, WeightMode::Bf16Resident, 21, &workload);
    let cont = serve(
        SchedPolicy::Continuous,
        2,
        WeightMode::Bf16Resident,
        21,
        &workload,
    );
    assert_eq!(stat.responses.len(), 7);
    assert_eq!(cont.responses.len(), 7);
    assert!(
        cont.queue_delay.mean() < stat.queue_delay.mean(),
        "continuous mean queue delay {} must beat static {}",
        cont.queue_delay.mean(),
        stat.queue_delay.mean()
    );
    assert!(
        cont.ttft.mean() < stat.ttft.mean(),
        "continuous mean ttft {} must beat static {}",
        cont.ttft.mean(),
        stat.ttft.mean()
    );
    // Identical tokens regardless (the equivalence property again).
    assert_eq!(tokens_by_id(&stat), tokens_by_id(&cont));
}

/// The paper's freed-memory story as scheduler behavior: under the
/// same simulated HBM budget, the DF11 engine (smaller resident
/// weights) sustains at least as many concurrent decode slots as BF16
/// — here strictly more, because the budget leaves BF16 exactly one
/// request's worth of KV pages.
#[test]
fn df11_sustains_more_slots_than_bf16_under_same_hbm_budget() {
    // Mid-size config so DF11's compression gap dwarfs per-tensor
    // overheads (codebooks amortize poorly at test_tiny scale).
    let cfg = ModelConfig {
        name: "mid".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 256,
        max_seq_len: 64,
        tie_embeddings: false,
    };
    let seed = 4;
    let page_tokens = 16u64;
    let workload: Vec<Request> = (0..4)
        .map(|i| Request::new(vec![i as u32 + 1, 2], 4))
        .collect();
    // Worst case per request: 2 prompt + 4 generated - 1 = 5 tokens
    // -> 1 page of 16. Budget: BF16 resident weights + exactly 1 page.
    let bf16_resident = Engine::build(&cfg, seed, WeightMode::Bf16Resident)
        .unwrap()
        .resident_weight_bytes();
    let budget = bf16_resident + page_tokens * cfg.kv_bytes_per_token();

    let run = |mode: WeightMode| {
        let engine = Engine::build(&cfg, seed, mode).unwrap();
        let mut server = Server::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                policy: SchedPolicy::Continuous,
                hbm_bytes: Some(budget),
                page_tokens,
                ..SchedulerConfig::default()
            },
        );
        for r in &workload {
            server.submit(r.clone()).unwrap();
        }
        server.drain().unwrap()
    };

    let bf16 = run(WeightMode::Bf16Resident);
    let df11 = run(WeightMode::Df11);
    // Both complete everything…
    assert_eq!(bf16.responses.len(), 4);
    assert_eq!(df11.responses.len(), 4);
    assert!(bf16
        .responses
        .iter()
        .all(|r| r.finish == FinishReason::MaxTokens));
    // …but BF16 is serialized to one slot while DF11's freed weight
    // memory admits real concurrency.
    assert_eq!(bf16.occupancy.peak, 1, "bf16 budget holds exactly one page");
    assert!(
        df11.occupancy.peak >= 2,
        "df11 must convert freed weight bytes into concurrent slots (peak {})",
        df11.occupancy.peak
    );
    assert!(df11.occupancy.peak >= bf16.occupancy.peak);
    // And the tokens still agree (losslessness under budget pressure).
    assert_eq!(tokens_by_id(&bf16), tokens_by_id(&df11));
}

/// Every completed response reports a nonzero TTFT and consistent
/// latency ordering, with staggered open-loop arrivals.
#[test]
fn staggered_arrivals_report_sane_latency_stats() {
    let workload: Vec<Request> = (0..6)
        .map(|i| Request::new(vec![i as u32 + 1, 5], 3).with_arrival(i as f64 * 1e-4))
        .collect();
    for policy in [SchedPolicy::Static, SchedPolicy::Continuous] {
        let report = serve(policy, 2, WeightMode::Df11, 17, &workload);
        assert_eq!(report.responses.len(), 6);
        for r in &report.responses {
            assert!(r.ttft > 0.0, "{policy:?} request {} ttft", r.id);
            assert!(r.queue_delay >= 0.0);
            assert!(r.ttft <= r.latency + 1e-15);
            assert!(r.tpot > 0.0);
        }
        assert!(report.ttft.mean() > 0.0);
        assert!(report.occupancy.peak >= 1);
        assert!(report.total_seconds > 0.0);
    }
}
