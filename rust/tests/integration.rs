//! Cross-module integration tests: compress -> serialize -> load ->
//! serve -> verify, all through public APIs only.

use dfloat11::coordinator::{Engine, Request, SchedulerConfig, Server, WeightMode};
use dfloat11::dfloat11::decompress::decompress_sequential;
use dfloat11::dfloat11::serial;
use dfloat11::gpu_sim::{Device, TransferModel};
use dfloat11::model::corpus::{corpus_split, word_level_perplexity};
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::ModelConfig;
use dfloat11::{Bf16, Df11Model, Df11Tensor};

fn small_cfg() -> ModelConfig {
    ModelConfig {
        name: "itest".into(),
        vocab_size: 96,
        d_model: 48,
        n_layers: 3,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 96,
        max_seq_len: 96,
        tie_embeddings: false,
    }
}

/// Full pipeline: generate -> compress every tensor -> serialize the
/// model -> reload -> decompress -> bit-compare against the originals.
#[test]
fn compress_serialize_reload_roundtrip() {
    let cfg = small_cfg();
    let raw = generate_model_weights(&cfg, 77);
    let mut model = Df11Model::new("itest");
    let mut originals = Vec::new();
    for (spec, w) in raw {
        let t = Df11Tensor::compress(&w).unwrap();
        originals.push((spec.name.clone(), w));
        model.push_group(dfloat11::dfloat11::TensorGroup {
            name: spec.name.clone(),
            tensors: vec![(spec.name, t)],
        });
    }
    let mut buf = Vec::new();
    serial::write_model(&mut buf, &model).unwrap();
    let reloaded = serial::read_model(&mut buf.as_slice()).unwrap();
    assert_eq!(reloaded.num_elements(), model.num_elements());
    for (name, w) in &originals {
        let g = reloaded.group(name).unwrap();
        let restored = g.tensors[0].1.decompress().unwrap();
        assert_eq!(&restored, w, "{name}");
        // The optimized sequential decoder agrees too.
        assert_eq!(&decompress_sequential(&g.tensors[0].1).unwrap(), w);
    }
}

/// Serving: all three weight modes produce token-identical outputs on
/// the same workload (Table 2's losslessness, through the whole stack).
#[test]
fn three_modes_serve_identically() {
    let cfg = small_cfg();
    let workload: Vec<Request> = (0..5)
        .map(|i| Request::new(vec![(i * 13 % 90 + 1) as u32, 2, 3], 6))
        .collect();
    let mut outputs = Vec::new();
    for mode in [
        WeightMode::Bf16Resident,
        WeightMode::Df11,
        WeightMode::OffloadBf16 {
            resident_layers: 1,
            transfer: TransferModel::for_device(&Device::a100_40g()),
        },
    ] {
        let engine = Engine::build(&cfg, 5, mode).unwrap();
        let mut server = Server::new(engine, SchedulerConfig::static_batch(2));
        for r in workload.clone() {
            server.submit(r).unwrap();
        }
        let report = server.drain().unwrap();
        outputs.push(
            report
                .responses
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(outputs[0], outputs[1], "df11 == bf16");
    assert_eq!(outputs[0], outputs[2], "offload == bf16");
}

/// Perplexity on the synthetic corpus is finite and exactly equal
/// between BF16 and DF11 (Table 2's perplexity columns).
#[test]
fn perplexity_identical_across_modes() {
    let cfg = small_cfg();
    let (_, eval) = corpus_split(600, 3);
    let eval: Vec<u32> = eval.into_iter().map(|t| t % cfg.vocab_size as u32).collect();
    let mut ppl = Vec::new();
    for mode in [WeightMode::Bf16Resident, WeightMode::Df11] {
        let mut e = Engine::build(&cfg, 6, mode).unwrap();
        let nll = e.nll_nats(&eval).unwrap();
        ppl.push(word_level_perplexity(nll, &eval));
    }
    assert!(ppl[0].is_finite() && ppl[0] > 1.0);
    assert_eq!(ppl[0], ppl[1], "word-level perplexity must match exactly");
}

/// Engines with different seeds produce different weights (sanity that
/// losslessness checks aren't comparing constants).
#[test]
fn different_seeds_differ() {
    let cfg = small_cfg();
    let mut a = Engine::build(&cfg, 1, WeightMode::Bf16Resident).unwrap();
    let mut b = Engine::build(&cfg, 2, WeightMode::Bf16Resident).unwrap();
    let out_a = a.generate(&[vec![1, 2, 3]], 8).unwrap();
    let out_b = b.generate(&[vec![1, 2, 3]], 8).unwrap();
    assert_ne!(out_a, out_b);
}

/// Special values (NaN/Inf/subnormal/zero) survive the full container
/// path inside a model tensor.
#[test]
fn special_values_survive_model_path() {
    let mut w: Vec<Bf16> = (0..5000)
        .map(|i| Bf16::from_f32((i as f32 - 2500.0) * 1e-4))
        .collect();
    w[0] = Bf16::from_f32(f32::NAN);
    w[1] = Bf16::from_f32(f32::INFINITY);
    w[2] = Bf16::from_f32(f32::NEG_INFINITY);
    w[3] = Bf16::from_bits(0x0001);
    w[4] = Bf16::from_bits(0x8000); // -0.0
    let t = Df11Tensor::compress(&w).unwrap();
    let mut buf = Vec::new();
    serial::write_tensor(&mut buf, &t).unwrap();
    let t2 = serial::read_tensor(&mut buf.as_slice()).unwrap();
    assert_eq!(t2.decompress().unwrap(), w);
}

/// The whole-model compression ratio at realistic matrix sizes lands in
/// the paper's Table 1 band.
#[test]
fn model_ratio_in_table1_band() {
    let cfg = ModelConfig {
        name: "ratio-test".into(),
        vocab_size: 512,
        d_model: 256,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: 512,
        max_seq_len: 64,
        tie_embeddings: false,
    };
    let engine = Engine::build(&cfg, 9, WeightMode::Df11).unwrap();
    let bf16_bytes = cfg.bf16_bytes();
    let ratio = 100.0 * engine.resident_weight_bytes() as f64 / bf16_bytes as f64;
    assert!(
        (64.0..76.0).contains(&ratio),
        "model ratio {ratio:.2}% outside the plausible Table 1 band"
    );
}
