//! The persistent decode-runtime suite: the pooled two-phase pipeline
//! must be bit-identical to the sequential decoder across randomized
//! tensor sizes × pool widths × stealing configurations, pool workers
//! must never leak across repeated engine construction, and task
//! panics must stay isolated to the task that raised them.

use dfloat11::bf16::Bf16;
use dfloat11::coordinator::{Engine, WeightMode};
use dfloat11::dfloat11::decompress::decompress_sequential;
use dfloat11::dfloat11::parallel::decompress_pooled_into;
use dfloat11::model::ModelConfig;
use dfloat11::rng::Rng;
use dfloat11::runtime::pool::WorkerPool;
use dfloat11::Df11Tensor;

fn gaussian(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

/// The pool stress matrix: randomized tensor sizes × widths 1/2/8 ×
/// stealing enabled/disabled, every cell bit-identical to
/// `decompress_sequential`. Output windows are position-derived, so no
/// placement or stealing decision may move a single bit.
#[test]
fn pooled_decode_matches_sequential_across_widths_and_stealing() {
    let mut rng = Rng::new(0xD_F11);
    let mut sizes: Vec<usize> = (0..10).map(|_| 1 + rng.next_index(120_000)).collect();
    // Always include the degenerate and cutoff-straddling corners.
    sizes.extend([1, 2, 1023, 1024, 32 * 1024, 32 * 1024 + 1]);
    let pools: Vec<_> = [1usize, 2, 8]
        .iter()
        .flat_map(|&w| {
            [
                WorkerPool::with_config(w, true),
                WorkerPool::with_config(w, false),
            ]
        })
        .collect();
    for (i, &n) in sizes.iter().enumerate() {
        let ws = gaussian(n, 1000 + i as u64);
        let t = Df11Tensor::compress(&ws).unwrap();
        let seq = decompress_sequential(&t).unwrap();
        assert_eq!(seq, ws, "sequential decode must roundtrip (n={n})");
        for pool in &pools {
            for hint in [0usize, 1, pool.width()] {
                let mut out = vec![Bf16::from_bits(0); n];
                let stats = decompress_pooled_into(&t, &mut out, hint, pool).unwrap();
                assert_eq!(
                    out,
                    seq,
                    "n={n} width={} stealing={} hint={hint}",
                    pool.width(),
                    pool.stealing()
                );
                assert!(stats.threads >= 1 && stats.threads <= pool.width());
            }
        }
    }
}

/// Long-code-dense streams are the stealing stress case: deep codes
/// cluster decode work into a few stripes, so the work-stealing path
/// actually executes — and must still be bit-exact.
#[test]
fn stealing_survives_long_code_dense_streams() {
    // Exact power-of-two frequencies give code lengths 1..=18; the deep
    // symbols cluster in the second half of the stream.
    let mut exps = Vec::new();
    for i in 0..18u32 {
        let sym = 60 + i as u8;
        for _ in 0..(1usize << (17 - i)) {
            exps.push(sym);
        }
    }
    exps.push(90);
    let ws: Vec<Bf16> = exps
        .iter()
        .enumerate()
        .map(|(i, &e)| Bf16::from_parts(e, (i * 131 % 256) as u8))
        .collect();
    let t = Df11Tensor::compress(&ws).unwrap();
    let seq = decompress_sequential(&t).unwrap();
    for stealing in [true, false] {
        let pool = WorkerPool::with_config(8, stealing);
        let mut out = vec![Bf16::from_bits(0); ws.len()];
        decompress_pooled_into(&t, &mut out, 8, &pool).unwrap();
        assert_eq!(out, seq, "stealing={stealing}");
    }
}

/// A panicking pool task is reported as a typed error on its handle;
/// the worker that ran it survives and keeps serving.
#[test]
fn task_panic_is_isolated_and_pool_survives() {
    let pool = WorkerPool::new(2);
    let err = pool.scope(|scope| {
        let h = scope.spawn(|| -> u32 { panic!("intentional test panic") });
        h.join().unwrap_err()
    });
    assert!(
        err.to_string().contains("pool task panicked"),
        "got: {err}"
    );
    assert_eq!(pool.live_workers(), 2, "panic must not kill a worker");
    // The same pool still decodes correctly afterwards.
    let ws = gaussian(50_000, 7);
    let t = Df11Tensor::compress(&ws).unwrap();
    let mut out = vec![Bf16::from_bits(0); ws.len()];
    decompress_pooled_into(&t, &mut out, 2, &pool).unwrap();
    assert_eq!(out, ws);
}

/// Dropping a pool joins every worker — the probe outlives the pool
/// and observes zero live workers after the drop returns.
#[test]
fn pool_drop_joins_all_workers() {
    for width in [1usize, 3, 8] {
        let pool = WorkerPool::new(width);
        let probe = pool.probe();
        assert_eq!(pool.live_workers(), width);
        pool.scope(|scope| {
            for _ in 0..width * 4 {
                scope.spawn(std::thread::yield_now);
            }
        });
        drop(pool);
        assert_eq!(probe.live_workers(), 0, "width {width} leaked workers");
    }
}

/// Repeated `Engine` construction + serving + drop must not leak
/// workers: every default-built engine shares the *same* crate-global
/// pool (spawned once), and a dedicated pool attached to an engine has
/// all of its workers joined once the engine drops (observed through a
/// probe that outlives the pool).
#[test]
fn repeated_engine_construction_leaks_no_workers() {
    let cfg = ModelConfig::test_tiny();
    let global = WorkerPool::global();
    let mut probes = Vec::new();
    for seed in 0..6u64 {
        let mut e = Engine::build(&cfg, seed, WeightMode::Df11).unwrap();
        e.reset(1);
        e.step(&[seed as u32 % 16]).unwrap();
        assert!(
            std::sync::Arc::ptr_eq(&e.decode_pool(), &global),
            "default engines must share the one global pool, not spawn their own"
        );
        drop(e);
        // A dedicated pool lives exactly as long as its engine.
        let mut d = Engine::build(&cfg, seed, WeightMode::Df11).unwrap();
        let dedicated = WorkerPool::new(3);
        probes.push(dedicated.probe());
        d.set_decode_pool(dedicated);
        d.reset(1);
        d.step(&[2]).unwrap();
        drop(d);
    }
    for (i, probe) in probes.iter().enumerate() {
        assert_eq!(
            probe.live_workers(),
            0,
            "engine cycle {i} leaked dedicated-pool workers"
        );
    }
    assert_eq!(
        global.live_workers(),
        global.width(),
        "the global pool's workers stay resident for the process"
    );
}

/// The dedicated-pool path (`serve --threads T`) produces the same
/// tokens as the shared-pool default, at every width.
#[test]
fn dedicated_pool_tokens_match_shared_pool() {
    let cfg = ModelConfig::test_tiny();
    let prompts = vec![vec![3u32, 4, 5], vec![6u32]];
    let mut base = Engine::build(&cfg, 21, WeightMode::Df11).unwrap();
    let expect = base.generate(&prompts, 6).unwrap();
    for width in [1usize, 2, 8] {
        let mut e = Engine::build(&cfg, 21, WeightMode::Df11).unwrap();
        e.set_decode_pool(WorkerPool::new(width));
        e.set_decode_threads(width);
        assert_eq!(
            e.generate(&prompts, 6).unwrap(),
            expect,
            "width {width} diverged"
        );
    }
}
