//! Failure injection: corrupted containers and hostile inputs must be
//! *detected* — never panic, never return silently-wrong weights
//! without an error, never read out of bounds.
//!
//! The serialized format carries a CRC, so byte-level corruption is
//! caught at load. These tests also attack the post-deserialization
//! surfaces (the kernel's own validation) by corrupting in-memory
//! structures through the public KernelInput API.

use dfloat11::bf16::Bf16;
use dfloat11::dfloat11::serial::{read_tensor, write_tensor};
use dfloat11::dfloat11::Df11Tensor;
use dfloat11::gpu_sim::{DecompressKernel, KernelInput};
use dfloat11::huffman::lut::HierarchicalLut;
use dfloat11::proptest_lite::{check, Config};
use dfloat11::rng::Rng;

fn gaussian(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

/// Random single-byte flips anywhere in a serialized tensor are always
/// caught (CRC or structural validation) — never a panic, never an Ok
/// with wrong bytes.
#[test]
fn prop_serialized_bitflips_detected() {
    let ws = gaussian(20_000, 1);
    let t = Df11Tensor::compress(&ws).unwrap();
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();

    check(
        "bitflip-detect",
        Config {
            cases: 64,
            ..Config::default()
        },
        |g| {
            let mut corrupted = buf.clone();
            let pos = g.usize_in(0, corrupted.len() - 1);
            let bit = 1u8 << g.usize_in(0, 7);
            corrupted[pos] ^= bit;
            match read_tensor(&mut corrupted.as_slice()) {
                Err(_) => Ok(()), // detected at load: good
                Ok(t2) => {
                    // The flip landed in a spot the CRC covers, so this
                    // is unreachable for this format — but if a future
                    // format version relaxes coverage, decompression
                    // must still either error or return correct data.
                    match t2.decompress() {
                        Err(_) => Ok(()),
                        Ok(back) if back == ws => Ok(()),
                        Ok(_) => Err(format!(
                            "silent corruption: flip at byte {pos} bit {bit} accepted"
                        )),
                    }
                }
            }
        },
    );
}

/// Truncations at every length are rejected.
#[test]
fn truncation_at_any_point_detected() {
    let ws = gaussian(3000, 2);
    let t = Df11Tensor::compress(&ws).unwrap();
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();
    for cut in (0..buf.len() - 1).step_by(97) {
        assert!(
            read_tensor(&mut &buf[..cut]).is_err(),
            "truncation to {cut} bytes must fail"
        );
    }
}

/// Kernel-level attacks through KernelInput: every mismatch is an
/// error, not a panic or out-of-bounds access.
#[test]
fn kernel_input_attacks_rejected() {
    let ws = gaussian(10_000, 3);
    let t = Df11Tensor::compress(&ws).unwrap();
    let config = t.default_config();
    let lut = HierarchicalLut::build(t.codebook()).unwrap();
    let kernel = DecompressKernel::new(&lut, config);
    let good = KernelInput {
        encoded: t.encoded(),
        bit_len: t.bit_len(),
        gaps: &t.aux().gaps,
        block_output_pos: &t.aux().block_output_pos,
        packed_sign_mantissa: t.packed_sign_mantissa(),
    };
    let mut out = vec![Bf16::from_bits(0); ws.len()];
    kernel.run(&good, &mut out).unwrap();
    assert_eq!(out, ws);

    // bit_len larger than the buffer.
    let mut bad = good;
    bad.bit_len = t.encoded().len() as u64 * 8 + 1;
    assert!(kernel.run(&bad, &mut out).is_err());

    // bit_len shorter than the real stream: element counts disagree.
    let mut bad = good;
    bad.bit_len = t.bit_len() / 2;
    assert!(kernel.run(&bad, &mut out).is_err());

    // Gap array too short / too long.
    let short_gaps = &t.aux().gaps[..t.aux().gaps.len() - 1];
    let mut bad = good;
    bad.gaps = short_gaps;
    assert!(kernel.run(&bad, &mut out).is_err());

    // Sign/mantissa plane shorter than the element count.
    let mut bad = good;
    bad.packed_sign_mantissa = &t.packed_sign_mantissa()[..ws.len() - 1];
    assert!(kernel.run(&bad, &mut out).is_err());

    // Non-monotone block output positions.
    let mut bop = t.aux().block_output_pos.clone();
    if bop.len() >= 3 {
        bop.swap(0, 1);
        let mut bad = good;
        bad.block_output_pos = &bop;
        assert!(kernel.run(&bad, &mut out).is_err());
    }

    // Encoded stream swapped with random garbage of the same size:
    // either an invalid-prefix error or a count mismatch — never Ok
    // with wrong data and never a panic.
    let mut rng = Rng::new(4);
    let garbage: Vec<u8> = (0..t.encoded().len())
        .map(|_| rng.next_u32() as u8)
        .collect();
    let mut bad = good;
    bad.encoded = &garbage;
    match kernel.run(&bad, &mut out) {
        Err(_) => {}
        Ok(_) => {
            assert_ne!(out, ws, "garbage cannot reproduce the weights");
        }
    }
}

/// The sequential decoder survives the same garbage-stream attack.
#[test]
fn sequential_decoder_rejects_truncated_streams() {
    use dfloat11::dfloat11::decompress::decompress_sequential;
    let ws = gaussian(5000, 5);
    let t = Df11Tensor::compress(&ws).unwrap();
    // Sanity first.
    assert_eq!(decompress_sequential(&t).unwrap(), ws);

    // A tensor deserialized from a stream whose encoded section was
    // zeroed: wrong symbol stream -> either error or mismatch detection
    // by the caller; must not panic.
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();
    // (CRC catches it at read; force the in-memory path instead.)
    let tz = Df11Tensor::compress(&gaussian(5000, 6)).unwrap();
    let a = decompress_sequential(&tz).unwrap();
    assert_ne!(a, ws);
}

/// Zero-sized and maximal-value edge containers.
#[test]
fn edge_containers() {
    // All-identical weights: single-symbol codebook, 1-bit codes.
    let ws = vec![Bf16::from_f32(0.5); 4096];
    let t = Df11Tensor::compress(&ws).unwrap();
    assert_eq!(t.decompress().unwrap(), ws);
    assert!(t.stats().ratio_percent() < 70.0);

    // Alternating extreme exponents.
    let ws: Vec<Bf16> = (0..4096)
        .map(|i| {
            if i % 2 == 0 {
                Bf16::from_bits(0x0080) // smallest normal
            } else {
                Bf16::from_bits(0x7F00) // huge
            }
        })
        .collect();
    let t = Df11Tensor::compress(&ws).unwrap();
    assert_eq!(t.decompress().unwrap(), ws);
}
