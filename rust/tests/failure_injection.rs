//! Failure injection: corrupted containers and hostile inputs must be
//! *detected* — never panic, never return silently-wrong weights
//! without an error, never read out of bounds.
//!
//! The serialized format carries a CRC, so byte-level corruption is
//! caught at load. These tests also attack the post-deserialization
//! surfaces (the kernel's own validation) by corrupting in-memory
//! structures through the public KernelInput API.

use dfloat11::bf16::Bf16;
use dfloat11::container::write_df11_model;
use dfloat11::coordinator::{
    Engine, Fleet, ReplicaHealth, Request, RoundRobin, SchedulerConfig, ServeConfig, Server,
    ServingEngine, ShardedEngine, WeightMode,
};
use dfloat11::dfloat11::serial::{read_tensor, write_tensor};
use dfloat11::dfloat11::{Df11Model, Df11Tensor};
use dfloat11::error::Error;
use dfloat11::fuzz::{check_bytes, map_header, reference_container};
use dfloat11::gpu_sim::{DecompressKernel, Device, KernelInput};
use dfloat11::huffman::lut::HierarchicalLut;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::ModelConfig;
use dfloat11::multi_gpu::{plan_layer_sharding, ShardFormat};
use dfloat11::proptest_lite::{check, Config};
use dfloat11::rng::Rng;

fn gaussian(n: usize, seed: u64) -> Vec<Bf16> {
    let mut rng = Rng::new(seed);
    let mut xs = vec![0f32; n];
    rng.fill_gaussian_f32(&mut xs, 0.02);
    xs.into_iter().map(Bf16::from_f32).collect()
}

/// Random single-byte flips anywhere in a serialized tensor are always
/// caught (CRC or structural validation) — never a panic, never an Ok
/// with wrong bytes.
#[test]
fn prop_serialized_bitflips_detected() {
    let ws = gaussian(20_000, 1);
    let t = Df11Tensor::compress(&ws).unwrap();
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();

    check(
        "bitflip-detect",
        Config {
            cases: 64,
            ..Config::default()
        },
        |g| {
            let mut corrupted = buf.clone();
            let pos = g.usize_in(0, corrupted.len() - 1);
            let bit = 1u8 << g.usize_in(0, 7);
            corrupted[pos] ^= bit;
            match read_tensor(&mut corrupted.as_slice()) {
                Err(_) => Ok(()), // detected at load: good
                Ok(t2) => {
                    // The flip landed in a spot the CRC covers, so this
                    // is unreachable for this format — but if a future
                    // format version relaxes coverage, decompression
                    // must still either error or return correct data.
                    match t2.decompress() {
                        Err(_) => Ok(()),
                        Ok(back) if back == ws => Ok(()),
                        Ok(_) => Err(format!(
                            "silent corruption: flip at byte {pos} bit {bit} accepted"
                        )),
                    }
                }
            }
        },
    );
}

/// Truncations at every length are rejected.
#[test]
fn truncation_at_any_point_detected() {
    let ws = gaussian(3000, 2);
    let t = Df11Tensor::compress(&ws).unwrap();
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();
    for cut in (0..buf.len() - 1).step_by(97) {
        assert!(
            read_tensor(&mut &buf[..cut]).is_err(),
            "truncation to {cut} bytes must fail"
        );
    }
}

/// Kernel-level attacks through KernelInput: every mismatch is an
/// error, not a panic or out-of-bounds access.
#[test]
fn kernel_input_attacks_rejected() {
    let ws = gaussian(10_000, 3);
    let t = Df11Tensor::compress(&ws).unwrap();
    let config = t.default_config();
    let lut = HierarchicalLut::build(t.codebook()).unwrap();
    let kernel = DecompressKernel::new(&lut, config);
    let good = KernelInput {
        encoded: t.encoded(),
        bit_len: t.bit_len(),
        gaps: &t.aux().gaps,
        block_output_pos: &t.aux().block_output_pos,
        packed_sign_mantissa: t.packed_sign_mantissa(),
    };
    let mut out = vec![Bf16::from_bits(0); ws.len()];
    kernel.run(&good, &mut out).unwrap();
    assert_eq!(out, ws);

    // bit_len larger than the buffer.
    let mut bad = good;
    bad.bit_len = t.encoded().len() as u64 * 8 + 1;
    assert!(kernel.run(&bad, &mut out).is_err());

    // bit_len shorter than the real stream: element counts disagree.
    let mut bad = good;
    bad.bit_len = t.bit_len() / 2;
    assert!(kernel.run(&bad, &mut out).is_err());

    // Gap array too short / too long.
    let short_gaps = &t.aux().gaps[..t.aux().gaps.len() - 1];
    let mut bad = good;
    bad.gaps = short_gaps;
    assert!(kernel.run(&bad, &mut out).is_err());

    // Sign/mantissa plane shorter than the element count.
    let mut bad = good;
    bad.packed_sign_mantissa = &t.packed_sign_mantissa()[..ws.len() - 1];
    assert!(kernel.run(&bad, &mut out).is_err());

    // Non-monotone block output positions.
    let mut bop = t.aux().block_output_pos.clone();
    if bop.len() >= 3 {
        bop.swap(0, 1);
        let mut bad = good;
        bad.block_output_pos = &bop;
        assert!(kernel.run(&bad, &mut out).is_err());
    }

    // Encoded stream swapped with random garbage of the same size:
    // either an invalid-prefix error or a count mismatch — never Ok
    // with wrong data and never a panic.
    let mut rng = Rng::new(4);
    let garbage: Vec<u8> = (0..t.encoded().len())
        .map(|_| rng.next_u32() as u8)
        .collect();
    let mut bad = good;
    bad.encoded = &garbage;
    match kernel.run(&bad, &mut out) {
        Err(_) => {}
        Ok(_) => {
            assert_ne!(out, ws, "garbage cannot reproduce the weights");
        }
    }
}

/// The sequential decoder survives the same garbage-stream attack.
#[test]
fn sequential_decoder_rejects_truncated_streams() {
    use dfloat11::dfloat11::decompress::decompress_sequential;
    let ws = gaussian(5000, 5);
    let t = Df11Tensor::compress(&ws).unwrap();
    // Sanity first.
    assert_eq!(decompress_sequential(&t).unwrap(), ws);

    // A tensor deserialized from a stream whose encoded section was
    // zeroed: wrong symbol stream -> either error or mismatch detection
    // by the caller; must not panic.
    let mut buf = Vec::new();
    write_tensor(&mut buf, &t).unwrap();
    // (CRC catches it at read; force the in-memory path instead.)
    let tz = Df11Tensor::compress(&gaussian(5000, 6)).unwrap();
    let a = decompress_sequential(&tz).unwrap();
    assert_ne!(a, ws);
}

/// Zero-sized and maximal-value edge containers.
#[test]
fn edge_containers() {
    // All-identical weights: single-symbol codebook, 1-bit codes.
    let ws = vec![Bf16::from_f32(0.5); 4096];
    let t = Df11Tensor::compress(&ws).unwrap();
    assert_eq!(t.decompress().unwrap(), ws);
    assert!(t.stats().ratio_percent() < 70.0);

    // Alternating extreme exponents.
    let ws: Vec<Bf16> = (0..4096)
        .map(|i| {
            if i % 2 == 0 {
                Bf16::from_bits(0x0080) // smallest normal
            } else {
                Bf16::from_bits(0x7F00) // huge
            }
        })
        .collect();
    let t = Df11Tensor::compress(&ws).unwrap();
    assert_eq!(t.decompress().unwrap(), ws);
}

// ---------------------------------------------------------------------------
// Container-level and fleet-level degradation (the hardening PR's
// graceful-degradation surface): corruption in a mixed-codec container
// is detected identically across every I/O backend, a fleet survives a
// replica whose container is corrupt, and injected shard failures are
// typed — never a panic, never a wedge, never silently-wrong tokens.
// ---------------------------------------------------------------------------

fn temp_model_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("df11_failure_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.df11", std::process::id()))
}

/// Corrupt each entry of a mixed-codec container (df11, rans,
/// split-stream, raw-bf16) in turn: every backend must reject exactly
/// the corrupted entry with a typed error, decode the other three
/// identically to the reference, and agree with the other backends.
#[test]
fn mixed_codec_payload_corruption_detected_across_backends() {
    let reference = reference_container(21);
    let map = map_header(&reference.bytes).unwrap();
    assert_eq!(map.entries.len(), 4, "one entry per codec");
    for (i, e) in map.entries.iter().enumerate() {
        let field = |at: usize| {
            let b: [u8; 8] = reference.bytes[at..at + 8].try_into().unwrap();
            u64::from_le_bytes(b)
        };
        let off = field(e.offset_off);
        let len = field(e.len_off);
        assert!(len > 0, "entry {i} has a payload to corrupt");
        let mut bytes = reference.bytes.clone();
        let mid = (off + len / 2) as usize;
        bytes[mid] ^= 0x10;
        let report = check_bytes(&format!("mixed{i}"), &bytes, &reference)
            .unwrap_or_else(|e| panic!("codec entry {i}: {e}"));
        assert!(report.opened, "header is untouched, open must succeed");
        assert_eq!(report.rejected, 1, "entry {i}: exactly the corrupted payload is rejected");
        assert_eq!(report.identical, 3, "entry {i}: the other codecs still decode clean");
    }
}

/// A fleet with one corrupt-container replica degrades instead of
/// wedging: the bad replica dies typed mid-serve (container payloads
/// are fetched lazily, so the build succeeds and the CRC mismatch
/// fires during decode), its requests re-route to the healthy replica,
/// and every token stream matches the single-healthy-server reference.
#[test]
fn fleet_corrupt_replica_degrades_gracefully() {
    let cfg = ModelConfig::test_tiny();
    let seed = 9u64;
    let raw = generate_model_weights(&cfg, seed);
    let model = Df11Model::compress_from_weights(cfg.name.clone(), raw).unwrap();
    let good_path = temp_model_path("good");
    let bad_path = temp_model_path("bad");
    write_df11_model(&good_path, &model).unwrap();
    let summary = write_df11_model(&bad_path, &model).unwrap();

    // Flip one payload byte past the header: open + header CRC still
    // pass, the damage only surfaces when that group is fetched.
    let mut bytes = std::fs::read(&bad_path).unwrap();
    let header = summary.header_bytes as usize;
    let mid = header + (bytes.len() - header) / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&bad_path, &bytes).unwrap();

    let n_reqs = 6usize;
    let workload: Vec<Vec<u32>> = (0..n_reqs).map(|i| vec![i as u32 + 1, 3]).collect();

    // Reference: one healthy server over the pristine container.
    let mut reference: Vec<Vec<u32>> = {
        let engine = Engine::build_from_container(&cfg, &good_path).unwrap();
        let mut server = Server::new(engine, SchedulerConfig::continuous(n_reqs));
        for prompt in &workload {
            server.submit(Request::new(prompt.clone(), 4)).unwrap();
        }
        let report = server.drain().unwrap();
        report.responses.iter().map(|r| r.tokens.clone()).collect()
    };
    reference.sort();

    let engines = vec![
        Engine::build_from_container(&cfg, &bad_path).unwrap(),
        Engine::build_from_container(&cfg, &good_path).unwrap(),
    ];
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(4).replicas(2),
        Box::new(RoundRobin::new()),
    )
    .unwrap();
    for prompt in &workload {
        fleet.submit(Request::new(prompt.clone(), 4)).unwrap();
    }
    let report = fleet.drain().unwrap();

    assert_eq!(report.responses.len(), n_reqs, "no request lost to the corrupt replica");
    assert!(!report.failures.is_empty(), "the corrupt replica's death is recorded");
    assert_eq!(report.failures[0].replica, 0);
    assert!(
        report.failures[0].error.contains("crc"),
        "typed corruption error, got: {}",
        report.failures[0].error
    );
    assert_eq!(report.per_replica[0].health, ReplicaHealth::Dead);
    let mut got: Vec<Vec<u32>> = report.responses.iter().map(|r| r.tokens.clone()).collect();
    got.sort();
    assert_eq!(got, reference, "degraded fleet tokens match the healthy reference");

    let _ = std::fs::remove_file(&good_path);
    let _ = std::fs::remove_file(&bad_path);
}

/// Injected shard failures are first-class typed errors on both engine
/// shapes, out-of-range shards are rejected up front, and a fleet
/// absorbs a sharded replica's mid-serve death without losing tokens.
#[test]
fn shard_failure_injection_is_typed_and_fleet_absorbs_it() {
    let cfg = ModelConfig::test_tiny();
    let seed = 11u64;
    let plan = plan_layer_sharding(&cfg, &Device::a100_80g(), 2, ShardFormat::Df11).unwrap();

    // Sharded engine: out-of-range rejected, in-range fires typed
    // naming the shard.
    let mut sharded = ShardedEngine::build(&cfg, seed, WeightMode::Bf16Resident, &plan).unwrap();
    assert!(matches!(
        sharded.inject_shard_failure(5, 1),
        Err(Error::InvalidArgument(_))
    ));
    sharded.inject_shard_failure(1, 2).unwrap();
    sharded.start_seq(1, &[1, 2, 3]).unwrap();
    let mut saw = None;
    for _ in 0..8 {
        match sharded.decode_step(&[1]) {
            Ok(_) => continue,
            Err(e) => {
                saw = Some(e);
                break;
            }
        }
    }
    match saw.expect("injected failure fires within the tick budget") {
        Error::ShardFailed { shard, reason } => {
            assert_eq!(shard, 1);
            assert!(reason.contains("injected"), "reason: {reason}");
        }
        other => panic!("expected ShardFailed, got: {other}"),
    }

    // Single-box engine: only shard 0 exists.
    let mut engine = Engine::build(&cfg, seed, WeightMode::Bf16Resident).unwrap();
    assert!(matches!(
        engine.inject_shard_failure(1, 0),
        Err(Error::InvalidArgument(_))
    ));
    engine.inject_shard_failure(0, 0).unwrap();
    engine.start_seq(1, &[1, 2]).unwrap();
    assert!(matches!(
        engine.decode_step(&[1]),
        Err(Error::ShardFailed { shard: 0, .. })
    ));

    // Fleet of sharded replicas: replica 0's shard 1 dies after one
    // tick; the fleet re-routes and finishes with reference tokens.
    let n_reqs = 4usize;
    let workload: Vec<Vec<u32>> = (0..n_reqs).map(|i| vec![i as u32 + 1]).collect();
    let mut reference: Vec<Vec<u32>> = {
        let healthy = Engine::build(&cfg, seed, WeightMode::Bf16Resident).unwrap();
        let mut server = Server::new(healthy, SchedulerConfig::continuous(n_reqs));
        for prompt in &workload {
            server.submit(Request::new(prompt.clone(), 3)).unwrap();
        }
        let report = server.drain().unwrap();
        report.responses.iter().map(|r| r.tokens.clone()).collect()
    };
    reference.sort();

    let mut failing = ShardedEngine::build(&cfg, seed, WeightMode::Bf16Resident, &plan).unwrap();
    failing.inject_shard_failure(1, 1).unwrap();
    let engines = vec![
        failing,
        ShardedEngine::build(&cfg, seed, WeightMode::Bf16Resident, &plan).unwrap(),
    ];
    let mut fleet = Fleet::new(
        engines,
        ServeConfig::new().slots(4).replicas(2),
        Box::new(RoundRobin::new()),
    )
    .unwrap();
    for prompt in &workload {
        fleet.submit(Request::new(prompt.clone(), 3)).unwrap();
    }
    let report = fleet.drain().unwrap();
    assert_eq!(report.responses.len(), n_reqs, "no request lost to the shard failure");
    assert!(!report.failures.is_empty());
    assert_eq!(report.failures[0].replica, 0);
    assert!(
        report.failures[0].error.contains("shard 1 failed"),
        "typed shard error surfaces in the fleet report, got: {}",
        report.failures[0].error
    );
    assert_eq!(report.per_replica[0].health, ReplicaHealth::Dead);
    let mut got: Vec<Vec<u32>> = report.responses.iter().map(|r| r.tokens.clone()).collect();
    got.sort();
    assert_eq!(got, reference, "sharded fleet degrades losslessly");
}
