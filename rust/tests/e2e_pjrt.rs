//! End-to-end tests over the PJRT runtime + AOT artifacts.
//!
//! Compiled only with `--features pjrt` (the runtime needs the vendored
//! `xla` bindings), then further gated on `artifacts/meta.json` (run
//! `make artifacts` first). Each test boots a real PJRT CPU client and
//! executes the JAX-lowered graphs.
#![cfg(feature = "pjrt")]

use dfloat11::coordinator::{Engine, NativeBackend, WeightMode};
use dfloat11::model::ModelConfig;
use dfloat11::runtime::{ArtifactMeta, XlaBackend};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

/// The XLA backend and the native backend agree numerically on the full
/// 100M model's decode step (same weights, same tokens).
#[test]
fn xla_and_native_backends_agree() {
    let Some(dir) = artifact_dir() else { return };
    let cfg = ModelConfig::tiny_100m();
    ArtifactMeta::load(&dir).unwrap().check_config(&cfg).unwrap();

    let mut native = Engine::build_with_backend(
        &cfg,
        123,
        WeightMode::Bf16Resident,
        Box::new(NativeBackend),
    )
    .unwrap();
    let mut xla = Engine::build_with_backend(
        &cfg,
        123,
        WeightMode::Bf16Resident,
        Box::new(XlaBackend::open(&dir).unwrap()),
    )
    .unwrap();

    native.reset(2);
    xla.reset(2);
    let tokens = [10u32, 200];
    let ln = native.step(&tokens).unwrap();
    let lx = xla.step(&tokens).unwrap();
    assert_eq!(ln.len(), lx.len());
    let mut max_rel = 0f32;
    for (a, b) in ln.iter().zip(&lx) {
        let rel = (a - b).abs() / a.abs().max(1e-3);
        max_rel = max_rel.max(rel);
    }
    assert!(
        max_rel < 2e-2,
        "native vs xla logits diverge: max rel err {max_rel}"
    );
    // Greedy decisions agree.
    let v = cfg.vocab_size;
    for b in 0..2 {
        let an = dfloat11::nn::argmax(&ln[b * v..(b + 1) * v]);
        let ax = dfloat11::nn::argmax(&lx[b * v..(b + 1) * v]);
        assert_eq!(an, ax, "greedy token differs on backend");
    }
}

/// DF11 vs BF16 through the *PJRT* backend: logits bitwise identical.
/// (The losslessness claim on the real artifact execution path.)
#[test]
fn df11_lossless_on_pjrt_path() {
    let Some(dir) = artifact_dir() else { return };
    let cfg = ModelConfig::tiny_100m();
    let mut bf16 = Engine::build_with_backend(
        &cfg,
        7,
        WeightMode::Bf16Resident,
        Box::new(XlaBackend::open(&dir).unwrap()),
    )
    .unwrap();
    let mut df11 = Engine::build_with_backend(
        &cfg,
        7,
        WeightMode::Df11,
        Box::new(XlaBackend::open(&dir).unwrap()),
    )
    .unwrap();
    bf16.reset(1);
    df11.reset(1);
    let lb = bf16.step(&[42]).unwrap();
    let ld = df11.step(&[42]).unwrap();
    assert_eq!(lb, ld, "DF11 must be bit-identical to BF16 through PJRT");
}

/// Unsupported batch sizes are rejected with a helpful error.
#[test]
fn unsupported_batch_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let cfg = ModelConfig::tiny_100m();
    let mut e = Engine::build_with_backend(
        &cfg,
        1,
        WeightMode::Bf16Resident,
        Box::new(XlaBackend::open(&dir).unwrap()),
    )
    .unwrap();
    e.reset(3); // artifacts exist for 1, 2, 4, 8
    let err = e.step(&[1, 2, 3]).unwrap_err().to_string();
    assert!(err.contains("batch 3"), "unhelpful error: {err}");
}

/// Wrong model config against the artifacts is rejected.
#[test]
fn config_mismatch_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let meta = ArtifactMeta::load(&dir).unwrap();
    let mut cfg = ModelConfig::tiny_100m();
    cfg.d_model *= 2;
    assert!(meta.check_config(&cfg).is_err());
}
