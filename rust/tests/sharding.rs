//! Cross-shard bit-identity: the sharded multi-engine path must be
//! indistinguishable — tokens *and* logits — from the single-box
//! engine, for every weight source (BF16, DF11, container range reads)
//! and both scheduler policies, at shard counts 1/2/4. Plus the
//! isolation property: no shard ever reads container groups outside
//! its `ShardPlan` assignment (checked via reader instrumentation).

use dfloat11::container::write_df11_model;
use dfloat11::coordinator::{
    shard_groups, ContainerSource, Engine, FinishReason, Request, SchedPolicy, SchedulerConfig,
    Server, ServingEngine, ShardedEngine, StepEvent, WeightMode, WeightSource,
};
use dfloat11::dfloat11::Df11Model;
use dfloat11::gpu_sim::Device;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::ModelConfig;
use dfloat11::multi_gpu::{plan_layer_sharding, shard_layer_ranges, ShardFormat, ShardPlan};
use dfloat11::proptest_lite::{check, Config};
use std::path::PathBuf;
use std::sync::Arc;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

fn tiny() -> ModelConfig {
    ModelConfig::test_tiny()
}

fn plan_for(cfg: &ModelConfig, shards: usize) -> ShardPlan {
    plan_layer_sharding(cfg, &Device::a100_80g(), shards, ShardFormat::Df11).unwrap()
}

fn temp_container(tag: &str, cfg: &ModelConfig, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir().join("df11_sharding_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}_{}.df11", std::process::id()));
    let raw = generate_model_weights(cfg, seed);
    let model = Df11Model::compress_from_weights(cfg.name.clone(), raw).unwrap();
    write_df11_model(&path, &model).unwrap();
    path
}

/// Drive one engine through the lifecycle on a fixed two-sequence
/// workload, recording every sampled token and every tick's logits.
fn run_lifecycle<E: ServingEngine + TickLogits>(engine: &mut E) -> (Vec<Vec<u32>>, Vec<Vec<f32>>) {
    let prompts: [&[u32]; 2] = [&[5, 6, 7], &[9]];
    let max_new = 6usize;
    engine.start_seq(1, prompts[0]).unwrap();
    engine.start_seq(2, prompts[1]).unwrap();
    let mut tokens = vec![Vec::new(), Vec::new()];
    let mut logit_ticks = Vec::new();
    let mut live = vec![1u64, 2u64];
    while !live.is_empty() {
        let outcomes = engine.decode_step(&live).unwrap();
        logit_ticks.push(engine.tick_logits());
        let mut retired = Vec::new();
        for o in outcomes {
            let idx = (o.seq_id - 1) as usize;
            match o.event {
                StepEvent::Prefill { .. } => {}
                StepEvent::Token(t) => {
                    tokens[idx].push(t);
                    if tokens[idx].len() >= max_new {
                        retired.push(o.seq_id);
                    }
                }
                StepEvent::CacheFull => retired.push(o.seq_id),
            }
        }
        for id in retired {
            engine.finish_seq(id).unwrap();
            live.retain(|&l| l != id);
        }
    }
    (tokens, logit_ticks)
}

/// Test-local extension: read the last tick's logits from any engine
/// (both shapes expose `last_logits`; the serving trait stays minimal).
trait TickLogits {
    fn tick_logits(&self) -> Vec<f32>;
}

impl TickLogits for Engine {
    fn tick_logits(&self) -> Vec<f32> {
        self.last_logits().to_vec()
    }
}

impl TickLogits for ShardedEngine {
    fn tick_logits(&self) -> Vec<f32> {
        self.last_logits().to_vec()
    }
}

/// THE acceptance property, in-memory sources: for N ∈ {1,2,4}, the
/// sharded engine's token streams AND per-tick logits are bit-identical
/// to the unsharded engine, for BF16 and DF11 weights.
#[test]
fn sharded_matches_unsharded_bitwise_bf16_and_df11() {
    let cfg = tiny();
    for mode in [WeightMode::Bf16Resident, WeightMode::Df11] {
        let mut solo = Engine::build(&cfg, 7, mode.clone()).unwrap();
        let (expect_tokens, expect_logits) = run_lifecycle(&mut solo);
        assert!(expect_tokens.iter().all(|t| !t.is_empty()));
        for shards in SHARD_COUNTS {
            let plan = plan_for(&cfg, shards);
            let mut sharded = ShardedEngine::build(&cfg, 7, mode.clone(), &plan).unwrap();
            let (tokens, logits) = run_lifecycle(&mut sharded);
            assert_eq!(
                tokens, expect_tokens,
                "{mode:?} tokens diverged at {shards} shards"
            );
            assert_eq!(
                logits.len(),
                expect_logits.len(),
                "{mode:?} tick count diverged at {shards} shards"
            );
            for (tick, (a, b)) in logits.iter().zip(&expect_logits).enumerate() {
                assert_eq!(a.len(), b.len(), "{mode:?} logit rows, tick {tick}");
                assert!(
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{mode:?} logits diverged at {shards} shards, tick {tick}"
                );
            }
        }
    }
}

/// Same acceptance for the container source: every shard streams only
/// its groups from disk, and the result is still bit-identical.
#[test]
fn sharded_container_matches_unsharded_bitwise() {
    let cfg = tiny();
    let path = temp_container("bitident", &cfg, 7);
    let mut solo = Engine::build_from_container(&cfg, &path).unwrap();
    let (expect_tokens, expect_logits) = run_lifecycle(&mut solo);
    for shards in SHARD_COUNTS {
        let plan = plan_for(&cfg, shards);
        let mut sharded = ShardedEngine::build_from_container(&cfg, &path, &plan).unwrap();
        let (tokens, logits) = run_lifecycle(&mut sharded);
        assert_eq!(tokens, expect_tokens, "container tokens at {shards} shards");
        for (tick, (a, b)) in logits.iter().zip(&expect_logits).enumerate() {
            assert_eq!(a.len(), b.len(), "container logit rows, tick {tick}");
            assert!(
                a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "container logits diverged at {shards} shards, tick {tick}"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

fn tokens_by_id(report: &dfloat11::coordinator::ServeReport) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<(u64, Vec<u32>)> = report
        .responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

fn serve_workload<E: ServingEngine>(
    engine: E,
    policy: SchedPolicy,
    slots: usize,
    workload: &[Request],
) -> dfloat11::coordinator::ServeReport {
    let mut server = Server::new(
        engine,
        SchedulerConfig {
            max_batch: slots,
            policy,
            ..SchedulerConfig::default()
        },
    );
    for r in workload {
        let at = r.arrival;
        server.submit_at(r.clone(), at).unwrap();
    }
    server.drain().unwrap()
}

/// Both scheduler policies over every source × shard count: the full
/// serving stack (queue → slots → engine) emits identical tokens
/// sharded and unsharded.
#[test]
fn server_emits_identical_tokens_across_shards_sources_and_policies() {
    let cfg = tiny();
    let seed = 13;
    let path = temp_container("server", &cfg, seed);
    let workload: Vec<Request> = (0..5)
        .map(|i| Request::new(vec![(i * 11 % 50 + 1) as u32, 7, 8], 3 + i % 4))
        .collect();

    for policy in [SchedPolicy::Static, SchedPolicy::Continuous] {
        for source in ["bf16", "df11", "container"] {
            let build_solo = || -> Engine {
                match source {
                    "bf16" => Engine::build(&cfg, seed, WeightMode::Bf16Resident).unwrap(),
                    "df11" => Engine::build(&cfg, seed, WeightMode::Df11).unwrap(),
                    _ => Engine::build_from_container(&cfg, &path).unwrap(),
                }
            };
            let expect = tokens_by_id(&serve_workload(build_solo(), policy, 2, &workload));
            assert_eq!(expect.len(), workload.len());
            for shards in SHARD_COUNTS {
                let plan = plan_for(&cfg, shards);
                let engine = match source {
                    "bf16" => {
                        ShardedEngine::build(&cfg, seed, WeightMode::Bf16Resident, &plan).unwrap()
                    }
                    "df11" => ShardedEngine::build(&cfg, seed, WeightMode::Df11, &plan).unwrap(),
                    _ => ShardedEngine::build_from_container(&cfg, &path, &plan).unwrap(),
                };
                let got = tokens_by_id(&serve_workload(engine, policy, 2, &workload));
                assert_eq!(
                    got, expect,
                    "{source} under {policy:?} diverged at {shards} shards"
                );
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Randomized equivalence: arbitrary mixed-length workloads, random
/// slot counts and shard counts — sharded serving may only change
/// latency, never tokens.
#[test]
fn prop_sharded_serving_is_token_invariant() {
    let cfg = tiny();
    let vocab = cfg.vocab_size as u32;
    check(
        "sharded-equivalence",
        Config {
            cases: 8,
            max_size: 32,
            ..Config::default()
        },
        |g| {
            let n_reqs = g.usize_in(1, 5);
            let slots = g.usize_in(1, 3);
            let shards = SHARD_COUNTS[g.usize_in(0, SHARD_COUNTS.len() - 1)];
            let policy = if g.usize_in(0, 1) == 0 {
                SchedPolicy::Static
            } else {
                SchedPolicy::Continuous
            };
            let workload: Vec<Request> = (0..n_reqs)
                .map(|_| {
                    let plen = g.usize_in(1, 4);
                    let prompt = g.vec_of(plen, |r| r.next_u32() % vocab);
                    Request::new(prompt, g.usize_in(1, 5))
                })
                .collect();
            let solo = Engine::build(&cfg, 3, WeightMode::Bf16Resident).unwrap();
            let expect = tokens_by_id(&serve_workload(solo, policy, slots, &workload));
            let plan = plan_for(&cfg, shards);
            let sharded = ShardedEngine::build(&cfg, 3, WeightMode::Bf16Resident, &plan).unwrap();
            let got = tokens_by_id(&serve_workload(sharded, policy, slots, &workload));
            if got != expect {
                return Err(format!(
                    "{n_reqs} reqs, {slots} slots, {shards} shards, {policy:?}: diverged"
                ));
            }
            Ok(())
        },
    );
}

/// The isolation property: serving a sharded workload, each shard's
/// container reader must only ever touch the groups its `ShardPlan`
/// range assigns to it — and never materialize the full model.
#[test]
fn no_shard_reads_container_groups_outside_its_assignment() {
    let cfg = tiny();
    let path = temp_container("isolation", &cfg, 21);
    let shards = 2usize;
    let plan = plan_for(&cfg, shards);
    let ranges = shard_layer_ranges(&plan);

    // Keep an Arc handle on each scoped source to audit it afterwards.
    let handles: Vec<Arc<ContainerSource>> = (0..shards)
        .map(|s| {
            let groups = shard_groups(&cfg, s, &ranges);
            Arc::new(ContainerSource::open_scoped(&path, &groups).unwrap())
        })
        .collect();
    let sources: Vec<Box<dyn WeightSource>> = handles
        .iter()
        .map(|h| Box::new(h.clone()) as Box<dyn WeightSource>)
        .collect();
    let engine = ShardedEngine::build_with_sources(&cfg, sources, &plan).unwrap();

    let total_payload: u64 = handles[0]
        .reader()
        .entries()
        .iter()
        .map(|e| e.len)
        .sum();
    let workload: Vec<Request> = (0..3).map(|i| Request::new(vec![i + 1, 2], 4)).collect();
    let report = serve_workload(engine, SchedPolicy::Continuous, 2, &workload);
    assert_eq!(report.responses.len(), 3);
    assert!(report
        .responses
        .iter()
        .all(|r| r.finish == FinishReason::MaxTokens));

    for (s, handle) in handles.iter().enumerate() {
        let assigned = shard_groups(&cfg, s, &ranges);
        let read = handle.reader().groups_read();
        assert!(
            !read.is_empty(),
            "shard {s} served tokens without reading its container slice?"
        );
        for g in &read {
            assert!(
                assigned.contains(g),
                "shard {s} read group {g} outside its assignment {assigned:?}"
            );
        }
        // No shard holds (or read) the whole model.
        assert!(
            handle.resident_weight_bytes() < total_payload,
            "shard {s} materialized the full container"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// The PR 3 freed-memory assertion, sharded: under the same *per-GPU*
/// HBM budget, DF11's smaller resident slice leaves every shard more
/// KV pages, so the DF11 sharded server sustains strictly more
/// concurrent slots than the BF16 one — with identical tokens.
#[test]
fn df11_shards_sustain_more_slots_than_bf16_under_same_per_gpu_budget() {
    // Mid-size config so DF11's compression gap dwarfs per-tensor
    // overheads (as in tests/scheduling.rs).
    let cfg = ModelConfig {
        name: "mid".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 256,
        max_seq_len: 64,
        tie_embeddings: false,
    };
    let seed = 4;
    let shards = 2usize;
    let page_tokens = SchedulerConfig::default().page_tokens;
    let plan = plan_for(&cfg, shards);
    let workload: Vec<Request> = (0..4)
        .map(|i| Request::new(vec![i as u32 + 1, 2], 4))
        .collect();

    // Per-GPU budget: the BF16 peak shard's resident bytes plus exactly
    // one page of its (per-shard, 1-of-2-layers) KV rate.
    let bf16_peak = ShardedEngine::build(&cfg, seed, WeightMode::Bf16Resident, &plan)
        .unwrap()
        .resident_weight_bytes();
    let shard_kv_per_token = cfg.kv_bytes_per_token() / cfg.n_layers as u64;
    let budget = bf16_peak + page_tokens * shard_kv_per_token;

    let run = |mode: WeightMode| {
        let engine = ShardedEngine::build(&cfg, seed, mode, &plan).unwrap();
        let mut server = Server::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                policy: SchedPolicy::Continuous,
                hbm_bytes: Some(budget),
                page_tokens,
                ..SchedulerConfig::default()
            },
        );
        for r in &workload {
            server.submit(r.clone()).unwrap();
        }
        server.drain().unwrap()
    };

    let bf16 = run(WeightMode::Bf16Resident);
    let df11 = run(WeightMode::Df11);
    assert_eq!(bf16.responses.len(), 4);
    assert_eq!(df11.responses.len(), 4);
    assert_eq!(
        bf16.occupancy.peak, 1,
        "bf16 per-GPU budget holds exactly one page on the peak shard"
    );
    assert!(
        df11.occupancy.peak >= 2,
        "df11's freed per-shard HBM must become concurrent slots (peak {})",
        df11.occupancy.peak
    );
    assert_eq!(tokens_by_id(&bf16), tokens_by_id(&df11));
}

/// The shard-overlap pipeline is a pure scheduling change: pipeline on
/// vs off must produce bit-identical tokens and per-tick logits, and
/// the simulated tick clock must charge the pipelined model no more
/// than the serial one (max-of-overlapped never exceeds the sum).
#[test]
fn pipelined_shard_ticks_are_bit_identical_to_serial() {
    let cfg = tiny();
    for shards in SHARD_COUNTS {
        let plan = plan_for(&cfg, shards);
        let mut on = ShardedEngine::build(&cfg, 11, WeightMode::Df11, &plan).unwrap();
        on.set_pipeline(true);
        let mut off = ShardedEngine::build(&cfg, 11, WeightMode::Df11, &plan).unwrap();
        off.set_pipeline(false);
        let (tokens_on, logits_on) = run_lifecycle(&mut on);
        let (tokens_off, logits_off) = run_lifecycle(&mut off);
        assert_eq!(tokens_on, tokens_off, "{shards} shards: pipeline changed tokens");
        assert_eq!(logits_on.len(), logits_off.len());
        for (tick, (a, b)) in logits_on.iter().zip(&logits_off).enumerate() {
            assert!(
                a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{shards} shards: pipeline changed logits at tick {tick}"
            );
        }
        for clock in [on.tick_clock(), off.tick_clock()] {
            assert!(clock.ticks > 0, "clock must accumulate ticks");
            assert!(
                clock.pipelined_seconds <= clock.serial_seconds + 1e-12,
                "max-of-overlapped must never exceed the serial sum \
                 (pipelined {} vs serial {})",
                clock.pipelined_seconds,
                clock.serial_seconds
            );
        }
    }
}
