//! Table 2: accuracy and perplexity are IDENTICAL between BF16 and DF11.
//!
//! The paper evaluates MMLU/TruthfulQA/WikiText/C4 through lm-eval; we
//! verify the strictly stronger property on the executable model: logits
//! are bitwise equal, so every downstream metric is equal. Reported
//! here: greedy-decoding agreement and word-level perplexity on the
//! synthetic held-out corpus, both modes, with timings.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{Engine, WeightMode};
use dfloat11::model::corpus::{corpus_split, word_level_perplexity};
use dfloat11::model::zoo;

fn main() {
    println!("# Table 2 — losslessness: BF16 vs DF11\n");
    let cfg = zoo::llama31_8b().scaled_down(12);
    let (_, eval) = corpus_split(4000, 7);
    let eval: Vec<u32> = eval.into_iter().map(|t| t % cfg.vocab_size as u32).collect();

    let mut table = Table::new(&[
        "model", "data type", "greedy tokens (64 steps)", "word ppl", "eval time",
    ]);
    let mut outputs: Vec<(Vec<Vec<u32>>, f64)> = Vec::new();
    for (label, mode) in [
        ("BF16", WeightMode::Bf16Resident),
        ("DF11 (ours)", WeightMode::Df11),
    ] {
        let mut engine = Engine::build(&cfg, 99, mode).expect("engine");
        let t0 = std::time::Instant::now();
        let gen = engine
            .generate(&[vec![1, 2, 3], vec![40, 41]], 64)
            .expect("generate");
        let nll = engine.nll_nats(&eval[..eval.len().min(200)]).expect("nll");
        let dt = t0.elapsed().as_secs_f64();
        let ppl = word_level_perplexity(nll, &eval[..eval.len().min(200)]);
        table.row(&[
            cfg.name.clone(),
            label.into(),
            format!("{}…", &format!("{:?}", gen[0])[..24.min(format!("{:?}", gen[0]).len())]),
            format!("{ppl:.6}"),
            fmt::seconds(dt),
        ]);
        outputs.push((gen, ppl));
    }
    table.print();

    assert_eq!(outputs[0].0, outputs[1].0, "greedy outputs must be identical");
    assert_eq!(outputs[0].1, outputs[1].1, "perplexity must be identical");
    println!(
        "\ngreedy outputs identical: YES; perplexity identical: YES (paper: \
         \"absolutely no loss in accuracy or perplexity\")"
    );
}
