//! Figures 8 & 9: BF16 component value distributions + ranked exponent
//! frequencies.
//!
//! Figure 8: sign/mantissa ~uniform, exponent sharply peaked.
//! Figure 9: exponent frequency decays rapidly with rank; only ~40 of
//! 256 values ever occur — which is what makes the 240..255 pointer
//! trick (§2.3.1) safe.

use dfloat11::bench_harness::Table;
use dfloat11::entropy::{exponent_histogram, ComponentHistograms};
use dfloat11::model::init::generate_weights;
use dfloat11::model::{zoo, WeightSpec};

fn main() {
    println!("# Figures 8/9 — BF16 component distributions\n");

    let cfg = zoo::llama31_8b();
    let spec = WeightSpec {
        name: "block.0.up_proj".into(),
        group: "block.0".into(),
        shape: [1, 1 << 21],
        fan_in: cfg.d_model,
    };
    let w = generate_weights(&spec, 33);

    let mut hist = ComponentHistograms::new();
    hist.record_weights(&w);

    // Figure 8: uniformity of sign and mantissa.
    let sf = hist.sign.frequencies();
    println!("sign: P(0) = {:.4}, P(1) = {:.4} (≈ 0.5 each)\n", sf[0], sf[1]);
    let mf = hist.mantissa.frequencies();
    let (mmin, mmax) = mf
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &p| (lo.min(p), hi.max(p)));
    println!(
        "mantissa: 128 values, min P {:.5}, max P {:.5} (near-uniform ≈ {:.5})\n",
        mmin,
        mmax,
        1.0 / 128.0
    );

    // Figure 9: ranked exponent frequencies.
    let eh = exponent_histogram(&w);
    println!(
        "exponent support: {} of 256 values used (paper: ~40); values >= 240 used: {}\n",
        eh.support_size(),
        eh.ranked().iter().filter(|(s, _)| *s >= 240).count()
    );
    let mut table = Table::new(&["rank", "exponent value", "2^(e-127)", "frequency", "cumulative"]);
    let total = eh.total() as f64;
    let mut cum = 0.0;
    for (rank, (sym, count)) in eh.ranked().into_iter().take(16).enumerate() {
        let p = count as f64 / total;
        cum += p;
        table.row(&[
            (rank + 1).to_string(),
            sym.to_string(),
            format!("2^{}", sym as i32 - 127),
            format!("{p:.5}"),
            format!("{cum:.5}"),
        ]);
    }
    table.print();
    println!(
        "\nshape: rapid (geometric) decay with rank — the top ~8 exponents \
         cover >90% of weights, giving ~2.6-bit entropy (Figure 1) and \
         short Huffman codes for the common cases."
    );

    // Safety check that underpins the compact LUT layout.
    assert_eq!(
        eh.ranked().iter().filter(|(s, _)| *s >= 240).count(),
        0,
        "exponents >= 240 must not occur in weight-like data"
    );
}
