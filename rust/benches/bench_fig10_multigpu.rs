//! Figure 10: multi-GPU decoding — BF16 vs DF11 on identical GPU
//! configurations (layer-sharded, Flash-Attention-era A100s).
//!
//! Analytic over the device model: shard feasibility, per-GPU memory,
//! and latency/throughput across batch sizes — plus the minimum-GPU
//! table that motivates DF11 (fewer devices for the same model).

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{Engine, ShardedEngine, WeightMode};
use dfloat11::gpu_sim::Device;
use dfloat11::model::zoo;
use dfloat11::multi_gpu::{min_gpus, plan_layer_sharding, step_latency, throughput, ShardFormat};
use std::time::Instant;

fn main() {
    println!("# Figure 10 — multi-GPU decoding: BF16 vs DF11\n");
    let device = Device::a100_80g();

    let cases = [
        (zoo::llama31_8b(), 1usize),
        (zoo::llama33_70b(), 2),
        (zoo::llama33_70b(), 4),
        (zoo::llama31_405b(), 8),
    ];

    let mut table = Table::new(&[
        "model",
        "gpus",
        "format",
        "max shard",
        "fits",
        "b=1 lat",
        "b=32 tok/s",
        "df11/bf16 tok/s",
    ]);
    for (model, gpus) in &cases {
        let mut tps = [0.0f64; 2];
        for (i, format) in [ShardFormat::Bf16, ShardFormat::Df11].into_iter().enumerate() {
            let plan = plan_layer_sharding(model, &device, *gpus, format).unwrap();
            let t32 = if plan.feasible {
                throughput(model, &plan, 32)
            } else {
                0.0
            };
            tps[i] = t32;
            table.row(&[
                model.name.clone(),
                gpus.to_string(),
                format!("{format:?}"),
                fmt::bytes(*plan.bytes_per_gpu.iter().max().unwrap()),
                if plan.feasible { "yes".into() } else { "NO".to_string() },
                if plan.feasible {
                    fmt::seconds(step_latency(model, &plan, 1))
                } else {
                    "-".into()
                },
                if plan.feasible { format!("{t32:.2}") } else { "-".into() },
                if i == 1 && tps[0] > 0.0 && tps[1] > 0.0 {
                    format!("{:.2}", tps[1] / tps[0])
                } else {
                    "".into()
                },
            ]);
        }
    }
    table.print();

    println!("\n## Minimum GPUs required (A100-80G)\n");
    let min_str = |model, f| match min_gpus(model, &device, f) {
        Ok(n) => n.to_string(),
        Err(_) => "infeasible".to_string(),
    };
    let mut t2 = Table::new(&["model", "bf16 min GPUs", "df11 min GPUs"]);
    for model in [zoo::llama31_8b(), zoo::llama33_70b(), zoo::llama31_405b()] {
        t2.row(&[
            model.name.clone(),
            min_str(&model, ShardFormat::Bf16),
            min_str(&model, ShardFormat::Df11),
        ]);
    }
    t2.print();
    println!(
        "\npaper shape: where both fit, DF11 throughput is below BF16 at small \
         batch (decompression on the critical path) and converges as batch \
         grows; DF11 needs materially fewer GPUs (405B: 8 vs >8). Preserved."
    );

    // ---- Executable cross-check ---------------------------------------
    // The analytic tables above predict; the sharded engine *executes*.
    // A scaled-down 8B runs on 1/2/4 shard engines: output tokens must
    // be bit-identical to the unsharded engine at every shard count,
    // and the measured per-shard work shifts where the plan says it
    // should (the CPU wall-clock is not an A100 latency — the analytic
    // column is the same plan's device-model estimate for reference).
    println!("\n## Executable cross-check (scaled-down 8B, CPU shard engines)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
    let new_tokens = 8;
    let mut solo = Engine::build(&cfg, 42, WeightMode::Df11).expect("unsharded engine");
    let t0 = Instant::now();
    let expect = solo.generate(&prompts, new_tokens).expect("unsharded run");
    let solo_dt = t0.elapsed().as_secs_f64();
    let total_tokens: usize = expect.iter().map(|t| t.len()).sum();

    let mut t3 = Table::new(&[
        "shards",
        "measured tok/s (CPU)",
        "analytic tok/s (A100)",
        "tokens == unsharded",
    ]);
    t3.row(&[
        "1 (baseline)".into(),
        format!("{:.2}", total_tokens as f64 / solo_dt),
        "-".into(),
        "yes".into(),
    ]);
    for shards in [1usize, 2, 4] {
        let plan =
            plan_layer_sharding(&cfg, &device, shards, ShardFormat::Df11).expect("plan");
        let mut engine =
            ShardedEngine::build(&cfg, 42, WeightMode::Df11, &plan).expect("sharded engine");
        let t0 = Instant::now();
        let got = engine.generate(&prompts, new_tokens).expect("sharded run");
        let dt = t0.elapsed().as_secs_f64();
        // The full-size model's analytic throughput on the same GPU
        // count, for shape comparison.
        let analytic = {
            let full = zoo::llama31_8b();
            let p = plan_layer_sharding(&full, &device, shards, ShardFormat::Df11)
                .expect("analytic plan");
            throughput(&full, &p, prompts.len() as u64)
        };
        t3.row(&[
            shards.to_string(),
            format!("{:.2}", total_tokens as f64 / dt),
            format!("{analytic:.2}"),
            if got == expect { "yes".into() } else { "NO".to_string() },
        ]);
        assert_eq!(
            got, expect,
            "sharded ({shards}) output diverged from the unsharded engine"
        );
    }
    t3.print();
    println!(
        "\nexecutable path agrees tokenwise with the single-box engine at \
         every shard count; per-shard timings flow into each shard's \
         breakdown (see `serve --shards`)."
    );

    // ---- Pipelined vs serial shard ticks ------------------------------
    // The shard-overlap pipeline decodes shard s+1's resident blocks on
    // the worker pool while shard s computes. The simulated tick clock
    // charges the serial model Σ(decode+compute) and the pipelined
    // model max-of-overlapped stages — both accumulated from the same
    // measured run, so the comparison is apples-to-apples.
    println!("\n## Pipelined vs serial shard ticks (simulated clock)\n");
    let mut t4 = Table::new(&[
        "shards",
        "ticks",
        "serial clock",
        "pipelined clock",
        "pipeline speedup",
        "tokens == serial run",
    ]);
    for shards in [2usize, 4] {
        let plan =
            plan_layer_sharding(&cfg, &device, shards, ShardFormat::Df11).expect("plan");
        let mut piped =
            ShardedEngine::build(&cfg, 42, WeightMode::Df11, &plan).expect("pipelined engine");
        piped.set_pipeline(true);
        let got_piped = piped.generate(&prompts, new_tokens).expect("pipelined run");
        let clock = piped.tick_clock();
        let mut serial =
            ShardedEngine::build(&cfg, 42, WeightMode::Df11, &plan).expect("serial engine");
        serial.set_pipeline(false);
        let got_serial = serial.generate(&prompts, new_tokens).expect("serial run");
        assert_eq!(
            got_piped, got_serial,
            "pipelining must not change a single token ({shards} shards)"
        );
        assert_eq!(got_piped, expect, "sharded output diverged from unsharded");
        assert!(
            clock.pipelined_seconds < clock.serial_seconds,
            "{shards} shards: pipelined ticks must beat serial ticks on the \
             simulated clock ({:.4}s vs {:.4}s)",
            clock.pipelined_seconds,
            clock.serial_seconds
        );
        t4.row(&[
            shards.to_string(),
            clock.ticks.to_string(),
            fmt::seconds(clock.serial_seconds),
            fmt::seconds(clock.pipelined_seconds),
            format!("{:.2}x", clock.serial_seconds / clock.pipelined_seconds),
            "yes".into(),
        ]);
    }
    t4.print();
    println!(
        "\nthe pipelined clock charges max(compute_s, decode_s+1) per stage \
         instead of their sum — decompression leaves the critical path, the \
         ZipServ-style resident decode pipeline on CPU shards."
    );
}
