//! Figure 10: multi-GPU decoding — BF16 vs DF11 on identical GPU
//! configurations (layer-sharded, Flash-Attention-era A100s).
//!
//! Analytic over the device model: shard feasibility, per-GPU memory,
//! and latency/throughput across batch sizes — plus the minimum-GPU
//! table that motivates DF11 (fewer devices for the same model).

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::gpu_sim::Device;
use dfloat11::model::zoo;
use dfloat11::multi_gpu::{min_gpus, plan_layer_sharding, step_latency, throughput, ShardFormat};

fn main() {
    println!("# Figure 10 — multi-GPU decoding: BF16 vs DF11\n");
    let device = Device::a100_80g();

    let cases = [
        (zoo::llama31_8b(), 1usize),
        (zoo::llama33_70b(), 2),
        (zoo::llama33_70b(), 4),
        (zoo::llama31_405b(), 8),
    ];

    let mut table = Table::new(&[
        "model",
        "gpus",
        "format",
        "max shard",
        "fits",
        "b=1 lat",
        "b=32 tok/s",
        "df11/bf16 tok/s",
    ]);
    for (model, gpus) in &cases {
        let mut tps = [0.0f64; 2];
        for (i, format) in [ShardFormat::Bf16, ShardFormat::Df11].into_iter().enumerate() {
            let plan = plan_layer_sharding(model, &device, *gpus, format).unwrap();
            let t32 = if plan.feasible {
                throughput(model, &plan, 32)
            } else {
                0.0
            };
            tps[i] = t32;
            table.row(&[
                model.name.clone(),
                gpus.to_string(),
                format!("{format:?}"),
                fmt::bytes(*plan.bytes_per_gpu.iter().max().unwrap()),
                if plan.feasible { "yes".into() } else { "NO".to_string() },
                if plan.feasible {
                    fmt::seconds(step_latency(model, &plan, 1))
                } else {
                    "-".into()
                },
                if plan.feasible { format!("{t32:.2}") } else { "-".into() },
                if i == 1 && tps[0] > 0.0 && tps[1] > 0.0 {
                    format!("{:.2}", tps[1] / tps[0])
                } else {
                    "".into()
                },
            ]);
        }
    }
    table.print();

    println!("\n## Minimum GPUs required (A100-80G)\n");
    let mut t2 = Table::new(&["model", "bf16 min GPUs", "df11 min GPUs"]);
    for model in [zoo::llama31_8b(), zoo::llama33_70b(), zoo::llama31_405b()] {
        t2.row(&[
            model.name.clone(),
            min_gpus(&model, &device, ShardFormat::Bf16).to_string(),
            min_gpus(&model, &device, ShardFormat::Df11).to_string(),
        ]);
    }
    t2.print();
    println!(
        "\npaper shape: where both fit, DF11 throughput is below BF16 at small \
         batch (decompression on the critical path) and converges as batch \
         grows; DF11 needs materially fewer GPUs (405B: 8 vs >8). Preserved."
    );
}
