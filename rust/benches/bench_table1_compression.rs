//! Table 1: compression factor + effective bit width across the model zoo.
//!
//! Two kinds of rows:
//! * **measured@scale** — a scaled-down model is fully generated,
//!   compressed tensor-by-tensor, and verified bit-exact;
//! * **sampled** — the paper-scale config's statistics, measured on
//!   weighted per-kind weight samples (no 800 GB materialization).
//!
//! Also prints the in-tree classical baseline (rANS, nvCOMP-style) on
//! the same bytes. zlib/zstd are not in the vendored dependency set, so
//! the ZipNN-style general-codec comparison uses rANS alone.
//!
//! Pass `--json PATH` (or set `DF11_BENCH_JSON`) to also write the
//! measurements — including the per-tensor auto-selection report with
//! achieved bits vs entropy — as `BENCH_table1.json`.

use dfloat11::bench_harness::json::{write_artifact, Json};
use dfloat11::bench_harness::{Bencher, Table};
use dfloat11::codec::select::{CodecSelector, SelectionPolicy};
use dfloat11::model::init::{generate_model_weights, sample_model_stats};
use dfloat11::model::zoo;
use dfloat11::Df11Tensor;

/// Paper Table 1 reference values: (name, ratio %, bits/weight).
const PAPER: &[(&str, f64, f64)] = &[
    ("Llama 3.1 8B Instruct", 67.84, 10.85),
    ("Llama 3.3 70B Instruct", 67.61, 10.82),
    ("Llama 3.1 405B Instruct", 67.91, 10.87),
    ("Qwen 3 14B", 68.17, 10.91),
    ("QwQ 32B", 68.14, 10.90),
    ("Mistral Nemo Instruct", 67.74, 10.84),
    ("Mistral Small 3", 67.58, 10.81),
    ("Phi 4 Reasoning Plus", 67.64, 10.82),
    ("DeepSeek R1 Distill Llama 8B", 67.81, 10.85),
];

fn main() {
    println!("# Table 1 — DF11 compression across the model zoo\n");
    let mut table = Table::new(&[
        "model",
        "mode",
        "orig (GB)",
        "df11 (GB)",
        "ratio %",
        "bits/w",
        "paper ratio %",
        "paper bits",
    ]);

    let mut sampled_rows: Vec<Json> = Vec::new();
    for (cfg, &(_, p_ratio, p_bits)) in zoo::table1_llms().iter().zip(PAPER) {
        let s = sample_model_stats(cfg, 128 * 1024, 42).expect("sample stats");
        let orig = cfg.bf16_bytes() as f64 / 1e9;
        sampled_rows.push(
            Json::obj()
                .field("model", Json::str(&cfg.name))
                .field("ratio_percent", Json::num(s.ratio_percent))
                .field("bits_per_weight", Json::num(s.bits_per_weight))
                .field("paper_ratio_percent", Json::num(p_ratio))
                .field("paper_bits_per_weight", Json::num(p_bits)),
        );
        table.row(&[
            cfg.name.clone(),
            "sampled".into(),
            format!("{orig:.2}"),
            format!("{:.2}", orig * s.ratio_percent / 100.0),
            format!("{:.2}", s.ratio_percent),
            format!("{:.2}", s.bits_per_weight),
            format!("{p_ratio:.2}"),
            format!("{p_bits:.2}"),
        ]);
    }

    // Fully-measured scaled model + roundtrip verification.
    let cfg = zoo::llama31_8b().scaled_down(8);
    let weights = generate_model_weights(&cfg, 42);
    let mut orig = 0u64;
    let mut comp = 0u64;
    for (_, w) in &weights {
        let t = Df11Tensor::compress(w).unwrap();
        assert_eq!(&t.decompress().unwrap(), w, "lossless");
        orig += t.original_bytes();
        comp += t.compressed_bytes();
    }
    table.row(&[
        cfg.name.clone(),
        "measured-full".into(),
        format!("{:.4}", orig as f64 / 1e9),
        format!("{:.4}", comp as f64 / 1e9),
        format!("{:.2}", 100.0 * comp as f64 / orig as f64),
        format!("{:.2}", comp as f64 * 8.0 / (orig as f64 / 2.0)),
        "~67.8".into(),
        "~10.9".into(),
    ]);
    table.print();

    // Classical baselines on one large tensor (ZipNN-style comparison).
    println!("\n## Classical lossless baselines (largest tensor)\n");
    let w = &weights.iter().max_by_key(|(_, w)| w.len()).unwrap().1;
    let bytes: Vec<u8> = w.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect();
    let mut b = Table::new(&["codec", "ratio %", "compress time"]);
    let bench = Bencher::from_env();

    let df11_t = Df11Tensor::compress(w).unwrap();
    let r = bench.bench("df11", || Df11Tensor::compress(w).unwrap());
    b.row(&[
        "DF11 (ours)".into(),
        format!("{:.2}", df11_t.stats().ratio_percent()),
        dfloat11::bench_harness::fmt::seconds(r.mean),
    ]);

    let (model, enc) = dfloat11::ans::compress_bf16_generic(w).unwrap();
    b.row(&[
        "rANS (nvCOMP-style)".into(),
        format!(
            "{:.2}",
            100.0 * dfloat11::ans::compressed_size(&model, &enc) as f64 / bytes.len() as f64
        ),
        "-".into(),
    ]);
    b.print();
    println!(
        "\npaper: DF11 ~68% vs nvCOMP ANS ~79%; generic codecs do not exploit \
         the exponent/mantissa split."
    );

    // Per-tensor auto selection on the measured model: the winning
    // codec per tensor plus the tracked gap to the Shannon bound.
    println!("\n## Auto codec selection (measured model)\n");
    let selector = CodecSelector::new(SelectionPolicy::Auto);
    let (_, report) = selector
        .select_model(weights.iter().map(|(spec, w)| {
            (
                spec.group.as_str(),
                spec.name.as_str(),
                &spec.shape[..],
                &w[..],
            )
        }))
        .expect("auto selection");
    let wins: Vec<String> = report
        .wins()
        .iter()
        .map(|(id, n)| format!("{} x{n}", id.label()))
        .collect();
    println!(
        "auto: {:.3} bits/w achieved vs {:.3} optimal (gap {:+.3}), ratio \
         {:.2}%, wins: {}",
        report.achieved_bits_per_weight(),
        report.optimal_bits_per_weight(),
        report.aggregate_gap_bits(),
        report.ratio_percent(),
        wins.join(", ")
    );

    let artifact = Json::obj()
        .field("bench", Json::str("table1_compression"))
        .field("sampled", Json::Array(sampled_rows))
        .field(
            "measured",
            Json::obj()
                .field("model", Json::str(&cfg.name))
                .field("original_bytes", Json::int(orig))
                .field("compressed_bytes", Json::int(comp))
                .field(
                    "ratio_percent",
                    Json::num(100.0 * comp as f64 / orig as f64),
                )
                .field(
                    "bits_per_weight",
                    Json::num(comp as f64 * 8.0 / (orig as f64 / 2.0)),
                ),
        )
        .field("selection", report.to_json());
    match write_artifact("table1", &artifact) {
        Ok(Some(path)) => println!("wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("artifact write failed: {e}"),
    }
}
