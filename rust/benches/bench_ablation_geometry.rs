//! Ablation: the design choices DESIGN.md calls out.
//!
//! 1. **Kernel geometry (T, n)** — the paper fixes T=256 threads/block,
//!    n=8 bytes/thread. Sweep both: effect on decode throughput, aux
//!    overhead (gap + output-position bytes), and SRAM footprint.
//! 2. **Hierarchical vs monolithic LUT** — why 256-entry tables: a
//!    flat 2^L table would not fit SRAM (L up to 32). Report k and
//!    SRAM bytes for realistic codebooks.
//! 3. **What to compress** — exponent-only (DF11) vs whole-value
//!    entropy coding (rANS baseline): ratio and decode speed.

use dfloat11::ans::{compress_bf16_generic, compressed_size, rans_decode};
use dfloat11::bench_harness::{fmt, Bencher, Table};
use dfloat11::bf16::Bf16;
use dfloat11::gpu_sim::KernelConfig;
use dfloat11::huffman::lut::HierarchicalLut;
use dfloat11::model::init::generate_weights;
use dfloat11::model::WeightSpec;
use dfloat11::Df11Tensor;

fn weights(n: usize) -> Vec<Bf16> {
    let spec = WeightSpec {
        name: "ablation".into(),
        group: "ablation".into(),
        shape: [1, n],
        fan_in: 4096,
    };
    generate_weights(&spec, 77)
}

fn main() {
    let bench = Bencher::from_env();
    let n = 1 << 20;
    let w = weights(n);

    // --- 1. geometry sweep ---
    println!("# Ablation 1 — kernel geometry (T threads/block, n bytes/thread)\n");
    let mut table = Table::new(&[
        "T", "n", "blocks", "aux bytes", "SRAM/block", "kernel decode",
    ]);
    for (t_per_block, n_bytes) in [
        (64usize, 4usize),
        (64, 8),
        (256, 4),
        (256, 8), // the paper's configuration
        (256, 16),
        (1024, 8),
    ] {
        let config = KernelConfig {
            threads_per_block: t_per_block,
            bytes_per_thread: n_bytes,
            parallelism: 1,
        };
        let t = Df11Tensor::compress_shaped(&w, &[n], &config).unwrap();
        let mut out = vec![Bf16::from_bits(0); n];
        let mut stats = None;
        let r = bench.bench("geom", || {
            stats = Some(t.decompress_with(&mut out, &config).unwrap());
        });
        let stats = stats.unwrap();
        let aux = (t.aux().gaps.len() * 5).div_ceil(8)
            + t.aux().block_output_pos.len() * 4;
        table.row(&[
            t_per_block.to_string(),
            n_bytes.to_string(),
            stats.blocks.to_string(),
            fmt::bytes(aux as u64),
            fmt::bytes(stats.peak_sram_bytes as u64),
            fmt::throughput_bps((n as f64 * 2.0) / r.mean),
        ]);
        assert_eq!(out, w);
    }
    table.print();
    println!(
        "\ntrade-off: larger T*n -> fewer blocks and less aux overhead but \
         bigger SRAM footprint and less parallel slack; the paper's \
         (256, 8) balances both — matching what the sweep shows.\n"
    );

    // --- 2. LUT hierarchy ---
    println!("# Ablation 2 — hierarchical LUTs vs monolithic table\n");
    let t = Df11Tensor::compress(&w).unwrap();
    let lut = HierarchicalLut::build(t.codebook()).unwrap();
    let l = t.codebook().max_len();
    let mut table = Table::new(&["design", "tables", "resident bytes"]);
    table.row(&[
        "monolithic 2^L".into(),
        "1".into(),
        fmt::bytes((1u64 << l.min(40)) * 2),
    ]);
    table.row(&[
        "hierarchical 256-entry (ours/paper)".into(),
        lut.num_tables().to_string(),
        fmt::bytes(lut.sram_bytes_general() as u64),
    ]);
    if let Some(compact) = lut.to_compact() {
        table.row(&[
            "compact u8 layout (paper §2.3.1)".into(),
            compact.num_tables().to_string(),
            fmt::bytes(compact.sram_bytes() as u64),
        ]);
    }
    table.print();
    println!(
        "\nL = {l} bits: a flat table would need 2^{l} entries — the \
         hierarchy is what makes SRAM-resident decoding possible.\n"
    );

    // --- 3. what to compress ---
    println!("# Ablation 3 — exponent-only (DF11) vs whole-value ANS\n");
    let mut table = Table::new(&["scheme", "ratio %", "decode"]);
    let mut out = vec![Bf16::from_bits(0); n];
    let r = bench.bench("df11", || {
        dfloat11::dfloat11::decompress::decompress_sequential_into(&t, &mut out).unwrap()
    });
    table.row(&[
        "DF11: Huffman(exponent) + raw sign/mantissa".into(),
        format!("{:.2}", t.stats().ratio_percent()),
        fmt::throughput_bps((n as f64 * 2.0) / r.mean),
    ]);
    let (model, enc) = compress_bf16_generic(&w).unwrap();
    let r = bench.bench("rans", || rans_decode(&model, &enc, n * 2).unwrap());
    table.row(&[
        "rANS over all 16 bits (NeuZip/nvCOMP style)".into(),
        format!(
            "{:.2}",
            100.0 * compressed_size(&model, &enc) as f64 / (n as f64 * 2.0)
        ),
        fmt::throughput_bps((n as f64 * 2.0) / r.mean),
    ]);
    table.print();
    println!(
        "\nthe split wins twice: near-uniform mantissa bits are skipped \
         (better ratio) and only ~2.75 bits/weight pass through the \
         entropy decoder (better speed)."
    );
}
