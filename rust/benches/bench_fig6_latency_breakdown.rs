//! Figure 6: per-component latency breakdown vs token batch size.
//!
//! The paper's ablation: DF11's decompression overhead is constant in
//! batch size, so it amortizes as the batch grows. Measured on the
//! executable engine (reduced scale), plus the analytic paper-scale
//! curve.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{Component, Engine, WeightMode};
use dfloat11::gpu_sim::Device;
use dfloat11::model::zoo;
use dfloat11::offload::{place, step_latency, PlacementMode};

fn main() {
    println!("# Figure 6 — latency breakdown vs batch size (Llama 3.1 8B)\n");

    // --- Measured at reduced scale ---
    println!("## Measured (scaled model, per decode step)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let mut table = Table::new(&[
        "batch",
        "mode",
        "embed",
        "decompress",
        "block compute",
        "lm head",
        "total/step",
    ]);
    for batch in [1usize, 2, 4, 8] {
        for (label, mode) in [
            ("BF16", WeightMode::Bf16Resident),
            ("DF11", WeightMode::Df11),
        ] {
            let mut engine = Engine::build(&cfg, 8, mode).unwrap();
            engine.reset(batch);
            let steps = 6usize;
            let tokens: Vec<u32> = (0..batch).map(|b| (b % 60 + 1) as u32).collect();
            for _ in 0..steps {
                engine.step(&tokens).unwrap();
            }
            let bd = &engine.breakdown;
            let per = |c| bd.measured_seconds(c) / steps as f64;
            let total = (bd.measured_seconds(Component::Embed)
                + bd.measured_seconds(Component::Decompress)
                + bd.measured_seconds(Component::BlockCompute)
                + bd.measured_seconds(Component::LmHead))
                / steps as f64;
            table.row(&[
                batch.to_string(),
                label.into(),
                fmt::seconds(per(Component::Embed)),
                fmt::seconds(per(Component::Decompress)),
                fmt::seconds(per(Component::BlockCompute)),
                fmt::seconds(per(Component::LmHead)),
                fmt::seconds(total),
            ]);
        }
    }
    table.print();

    // --- Analytic amortization curve at paper scale ---
    println!("\n## Estimated relative DF11 overhead at paper scale (A100-40G)\n");
    let model = zoo::llama31_8b();
    let device = Device::a100_40g();
    let df11 = place(&model, &device, PlacementMode::Df11, 1 << 30);
    let bf16 = place(&model, &device, PlacementMode::Bf16Resident, 1 << 30);
    let mut table = Table::new(&["batch", "bf16 step", "df11 step", "df11/bf16"]);
    for batch in [1u64, 8, 32, 128, 512, 2048] {
        let tb = step_latency(&model, &device, &bf16, batch);
        let td = step_latency(&model, &device, &df11, batch);
        table.row(&[
            batch.to_string(),
            fmt::seconds(tb),
            fmt::seconds(td),
            format!("{:.2}x", td / tb),
        ]);
    }
    table.print();
    println!(
        "\npaper shape: decompression cost is batch-invariant; the DF11/BF16 \
         ratio decays monotonically toward 1 as batch grows. Preserved."
    );
}
