//! Figure 6: per-component latency breakdown vs token batch size.
//!
//! The paper's ablation: DF11's decompression overhead is constant in
//! batch size, so it amortizes as the batch grows. Measured on the
//! executable engine (reduced scale), plus the analytic paper-scale
//! curve, plus the container payload I/O backend comparison (buffered
//! read vs zero-copy mmap vs prefetch ring) on a cold serve pass.
//!
//! Pass `--json PATH` (or set `DF11_BENCH_JSON`) to also write the
//! measurements as `BENCH_fig6.json`.

use dfloat11::bench_harness::json::{write_artifact, Json};
use dfloat11::bench_harness::{fmt, Table};
use dfloat11::bf16::Bf16;
use dfloat11::codec::{CompressedRef, DecodeOpts};
use dfloat11::container::ContainerWriter;
use dfloat11::coordinator::{Component, ContainerSource, Engine, WeightMode, WeightSource};
use dfloat11::crc32::Hasher;
use dfloat11::dfloat11::decompress::{decompress_sequential, decompress_sequential_into};
use dfloat11::gpu_sim::Device;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::zoo;
use dfloat11::offload::{place, step_latency, PlacementMode};
use dfloat11::{Df11Tensor, IoBackend};
use std::hint::black_box;
use std::time::Instant;

fn main() {
    println!("# Figure 6 — latency breakdown vs batch size (Llama 3.1 8B)\n");

    // --- Measured at reduced scale ---
    println!("## Measured (scaled model, per decode step)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let mut table = Table::new(&[
        "batch",
        "mode",
        "embed",
        "decompress",
        "block compute",
        "lm head",
        "total/step",
    ]);
    let mut measured_rows: Vec<Json> = Vec::new();
    for batch in [1usize, 2, 4, 8] {
        for (label, mode) in [
            ("BF16", WeightMode::Bf16Resident),
            ("DF11", WeightMode::Df11),
        ] {
            let mut engine = Engine::build(&cfg, 8, mode).unwrap();
            engine.reset(batch);
            let steps = 6usize;
            let tokens: Vec<u32> = (0..batch).map(|b| (b % 60 + 1) as u32).collect();
            for _ in 0..steps {
                engine.step(&tokens).unwrap();
            }
            let bd = &engine.breakdown;
            let per = |c| bd.measured_seconds(c) / steps as f64;
            let total = (bd.measured_seconds(Component::Embed)
                + bd.measured_seconds(Component::Decompress)
                + bd.measured_seconds(Component::BlockCompute)
                + bd.measured_seconds(Component::LmHead))
                / steps as f64;
            table.row(&[
                batch.to_string(),
                label.into(),
                fmt::seconds(per(Component::Embed)),
                fmt::seconds(per(Component::Decompress)),
                fmt::seconds(per(Component::BlockCompute)),
                fmt::seconds(per(Component::LmHead)),
                fmt::seconds(total),
            ]);
            measured_rows.push(
                Json::obj()
                    .field("batch", Json::int(batch as u64))
                    .field("mode", Json::str(label))
                    .field("embed_s", Json::num(per(Component::Embed)))
                    .field("decompress_s", Json::num(per(Component::Decompress)))
                    .field("block_compute_s", Json::num(per(Component::BlockCompute)))
                    .field("lm_head_s", Json::num(per(Component::LmHead)))
                    .field("total_s", Json::num(total)),
            );
        }
    }
    table.print();

    // --- Analytic amortization curve at paper scale ---
    println!("\n## Estimated relative DF11 overhead at paper scale (A100-40G)\n");
    let model = zoo::llama31_8b();
    let device = Device::a100_40g();
    let df11 = place(&model, &device, PlacementMode::Df11, 1 << 30);
    let bf16 = place(&model, &device, PlacementMode::Bf16Resident, 1 << 30);
    let mut table = Table::new(&["batch", "bf16 step", "df11 step", "df11/bf16"]);
    let mut analytic_rows: Vec<Json> = Vec::new();
    for batch in [1u64, 8, 32, 128, 512, 2048] {
        let tb = step_latency(&model, &device, &bf16, batch);
        let td = step_latency(&model, &device, &df11, batch);
        table.row(&[
            batch.to_string(),
            fmt::seconds(tb),
            fmt::seconds(td),
            format!("{:.2}x", td / tb),
        ]);
        analytic_rows.push(
            Json::obj()
                .field("batch", Json::int(batch))
                .field("bf16_step_s", Json::num(tb))
                .field("df11_step_s", Json::num(td))
                .field("ratio", Json::num(td / tb)),
        );
    }
    table.print();
    println!(
        "\npaper shape: decompression cost is batch-invariant; the DF11/BF16 \
         ratio decays monotonically toward 1 as batch grows. Preserved."
    );

    // --- Scratch-buffer reuse vs fresh allocation (per-block fetch) ---
    // The serving engine decompresses every transformer block into a
    // pooled scratch (BF16 staging + widened f32) instead of allocating
    // fresh Vecs per fetch. Measure one block's seven matrices both ways.
    println!("\n## Scratch-buffer reuse vs fresh allocation (per-block fetch)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let block: Vec<Df11Tensor> = generate_model_weights(&cfg, 11)
        .into_iter()
        .filter(|(spec, _)| spec.group == "block.0")
        .map(|(spec, w)| {
            Df11Tensor::compress_shaped(
                &w,
                &[spec.shape[0], spec.shape[1]],
                &dfloat11::gpu_sim::KernelConfig::for_elements(w.len()),
            )
            .unwrap()
        })
        .collect();
    let iters = if std::env::var("DF11_BENCH_QUICK").is_ok() {
        5usize
    } else {
        30
    };

    // Fresh-alloc path (the pre-pool engine): the same sequential
    // decoder, but a new Vec<Bf16> + Vec<f32> for every matrix of every
    // fetch — so the delta below isolates allocation, not decoder choice.
    let t0 = Instant::now();
    for _ in 0..iters {
        for t in &block {
            let w = decompress_sequential(t).unwrap();
            let f: Vec<f32> = w.iter().map(|b| b.to_f32()).collect();
            black_box(f.last().copied());
        }
    }
    let fresh = t0.elapsed().as_secs_f64() / iters as f64;

    // Pooled path: one BF16 staging buffer and one f32 buffer per slot,
    // resized (never reallocated once warm) across fetches.
    let mut staging: Vec<Bf16> = Vec::new();
    let mut widened: Vec<Vec<f32>> = (0..block.len()).map(|_| Vec::new()).collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        for (t, out) in block.iter().zip(widened.iter_mut()) {
            staging.resize(t.num_elements(), Bf16::from_bits(0));
            decompress_sequential_into(t, &mut staging).unwrap();
            out.clear();
            out.extend(staging.iter().map(|b| b.to_f32()));
            black_box(out.last().copied());
        }
    }
    let reused = t0.elapsed().as_secs_f64() / iters as f64;

    let mut table = Table::new(&["path", "per-block fetch", "allocs/fetch"]);
    table.row(&[
        "fresh Vec per fetch".into(),
        fmt::seconds(fresh),
        format!("{}", block.len() * 2),
    ]);
    table.row(&[
        "pooled scratch (engine)".into(),
        fmt::seconds(reused),
        "0 (steady state)".into(),
    ]);
    table.print();
    println!(
        "\nscratch reuse: {:.2}x vs fresh allocation over {} matrices/block",
        fresh / reused,
        block.len()
    );

    // --- Container payload I/O backends (cold serve pass) ---
    // One cold pass over every tensor of a container-backed model, per
    // payload backend: buffered read pays seek+copy in front of each
    // decode, mmap hands the decoder borrowed pages, and the ring reads
    // block i+1's payloads while block i decodes. Best-of-N cold
    // passes; the decoded bits must be identical everywhere.
    println!("\n## Container payload I/O backends (cold serve pass)\n");
    let weights = generate_model_weights(&cfg, 11);
    let compressed: Vec<(String, String, Df11Tensor)> = weights
        .iter()
        .map(|(spec, w)| {
            (
                spec.group.clone(),
                spec.name.clone(),
                Df11Tensor::compress(w).unwrap(),
            )
        })
        .collect();
    let mut writer = ContainerWriter::new("fig6-io");
    for (group, name, t) in &compressed {
        writer.push(group, name, CompressedRef::Df11(t));
    }
    let dir = std::env::temp_dir().join("df11_bench_fig6");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("io_{}.df11", std::process::id()));
    writer.write_to(&path).unwrap();
    let names: Vec<String> = compressed.iter().map(|(_, n, _)| n.clone()).collect();
    let trials = if std::env::var("DF11_BENCH_QUICK").is_ok() {
        2usize
    } else {
        4
    };

    // One cold pass: fresh source (empty payload cache), fetch every
    // tensor in container order, CRC the staged BF16 bits.
    let cold_pass = |backend: IoBackend, opts: &DecodeOpts| -> (f64, u32) {
        let src = ContainerSource::open_with(&path, backend).unwrap();
        let mut staging: Vec<Bf16> = Vec::new();
        let mut out: Vec<f32> = Vec::new();
        let mut h = Hasher::new();
        let t0 = Instant::now();
        for name in &names {
            src.fetch_into(name, opts, &mut staging, &mut out).unwrap();
            for w in &staging {
                h.update(&w.to_bits().to_le_bytes());
            }
        }
        (t0.elapsed().as_secs_f64(), h.finalize())
    };

    let mut table = Table::new(&["backend", "cold pass (best)", "vs read", "weights crc32"]);
    let mut io_rows: Vec<Json> = Vec::new();
    let mut best: Vec<(String, f64, u32)> = Vec::new();
    let serial = DecodeOpts::default();
    let no_prefetch = DecodeOpts::default().without_prefetch();
    for (label, backend, opts) in [
        ("read", IoBackend::Read, &serial),
        ("mmap", IoBackend::Mmap, &serial),
        ("ring", IoBackend::Ring, &serial),
        ("ring (no prefetch)", IoBackend::Ring, &no_prefetch),
    ] {
        let mut best_s = f64::INFINITY;
        let mut crc = 0u32;
        for _ in 0..trials {
            let (s, c) = cold_pass(backend, opts);
            best_s = best_s.min(s);
            crc = c;
        }
        best.push((label.to_string(), best_s, crc));
        io_rows.push(
            Json::obj()
                .field("backend", Json::str(label))
                .field("cold_pass_s", Json::num(best_s))
                .field("weights_crc32", Json::int(crc as u64)),
        );
    }
    let read_s = best[0].1;
    for (label, s, crc) in &best {
        table.row(&[
            label.clone(),
            fmt::seconds(*s),
            format!("{:.2}x", read_s / s),
            format!("{crc:08x}"),
        ]);
    }
    table.print();
    let read_crc = best[0].2;
    for (label, _, crc) in &best {
        assert_eq!(
            *crc, read_crc,
            "backend {label} decoded different bits than buffered read"
        );
    }
    let mmap_s = best[1].1;
    let ring_s = best[2].1;
    assert!(
        mmap_s.min(ring_s) <= read_s,
        "expected the zero-copy or overlapped backend to beat buffered \
         read on a cold pass: read={read_s:.6}s mmap={mmap_s:.6}s ring={ring_s:.6}s"
    );
    println!(
        "\ncold-pass identity: all backends decode crc32 {read_crc:08x}; \
         best non-copy backend is {:.2}x vs buffered read",
        read_s / mmap_s.min(ring_s)
    );
    std::fs::remove_file(&path).ok();

    let artifact = Json::obj()
        .field("bench", Json::str("fig6"))
        .field("provenance", Json::str("measured"))
        .field("model", Json::str(cfg.name.as_str()))
        .field("measured_breakdown", Json::Array(measured_rows))
        .field("analytic_paper_scale", Json::Array(analytic_rows))
        .field(
            "scratch_reuse",
            Json::obj()
                .field("fresh_alloc_s", Json::num(fresh))
                .field("pooled_s", Json::num(reused))
                .field("speedup", Json::num(fresh / reused)),
        )
        .field("io_backends", Json::Array(io_rows));
    match write_artifact("fig6", &artifact) {
        Ok(Some(path)) => println!("wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("artifact write failed: {e}"),
    }
}
