//! Figure 6: per-component latency breakdown vs token batch size.
//!
//! The paper's ablation: DF11's decompression overhead is constant in
//! batch size, so it amortizes as the batch grows. Measured on the
//! executable engine (reduced scale), plus the analytic paper-scale
//! curve.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::bf16::Bf16;
use dfloat11::coordinator::{Component, Engine, WeightMode};
use dfloat11::dfloat11::decompress::{decompress_sequential, decompress_sequential_into};
use dfloat11::gpu_sim::Device;
use dfloat11::model::init::generate_model_weights;
use dfloat11::model::zoo;
use dfloat11::offload::{place, step_latency, PlacementMode};
use dfloat11::Df11Tensor;
use std::hint::black_box;
use std::time::Instant;

fn main() {
    println!("# Figure 6 — latency breakdown vs batch size (Llama 3.1 8B)\n");

    // --- Measured at reduced scale ---
    println!("## Measured (scaled model, per decode step)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let mut table = Table::new(&[
        "batch",
        "mode",
        "embed",
        "decompress",
        "block compute",
        "lm head",
        "total/step",
    ]);
    for batch in [1usize, 2, 4, 8] {
        for (label, mode) in [
            ("BF16", WeightMode::Bf16Resident),
            ("DF11", WeightMode::Df11),
        ] {
            let mut engine = Engine::build(&cfg, 8, mode).unwrap();
            engine.reset(batch);
            let steps = 6usize;
            let tokens: Vec<u32> = (0..batch).map(|b| (b % 60 + 1) as u32).collect();
            for _ in 0..steps {
                engine.step(&tokens).unwrap();
            }
            let bd = &engine.breakdown;
            let per = |c| bd.measured_seconds(c) / steps as f64;
            let total = (bd.measured_seconds(Component::Embed)
                + bd.measured_seconds(Component::Decompress)
                + bd.measured_seconds(Component::BlockCompute)
                + bd.measured_seconds(Component::LmHead))
                / steps as f64;
            table.row(&[
                batch.to_string(),
                label.into(),
                fmt::seconds(per(Component::Embed)),
                fmt::seconds(per(Component::Decompress)),
                fmt::seconds(per(Component::BlockCompute)),
                fmt::seconds(per(Component::LmHead)),
                fmt::seconds(total),
            ]);
        }
    }
    table.print();

    // --- Analytic amortization curve at paper scale ---
    println!("\n## Estimated relative DF11 overhead at paper scale (A100-40G)\n");
    let model = zoo::llama31_8b();
    let device = Device::a100_40g();
    let df11 = place(&model, &device, PlacementMode::Df11, 1 << 30);
    let bf16 = place(&model, &device, PlacementMode::Bf16Resident, 1 << 30);
    let mut table = Table::new(&["batch", "bf16 step", "df11 step", "df11/bf16"]);
    for batch in [1u64, 8, 32, 128, 512, 2048] {
        let tb = step_latency(&model, &device, &bf16, batch);
        let td = step_latency(&model, &device, &df11, batch);
        table.row(&[
            batch.to_string(),
            fmt::seconds(tb),
            fmt::seconds(td),
            format!("{:.2}x", td / tb),
        ]);
    }
    table.print();
    println!(
        "\npaper shape: decompression cost is batch-invariant; the DF11/BF16 \
         ratio decays monotonically toward 1 as batch grows. Preserved."
    );

    // --- Scratch-buffer reuse vs fresh allocation (per-block fetch) ---
    // The serving engine decompresses every transformer block into a
    // pooled scratch (BF16 staging + widened f32) instead of allocating
    // fresh Vecs per fetch. Measure one block's seven matrices both ways.
    println!("\n## Scratch-buffer reuse vs fresh allocation (per-block fetch)\n");
    let cfg = zoo::llama31_8b().scaled_down(16);
    let block: Vec<Df11Tensor> = generate_model_weights(&cfg, 11)
        .into_iter()
        .filter(|(spec, _)| spec.group == "block.0")
        .map(|(spec, w)| {
            Df11Tensor::compress_shaped(
                &w,
                &[spec.shape[0], spec.shape[1]],
                &dfloat11::gpu_sim::KernelConfig::for_elements(w.len()),
            )
            .unwrap()
        })
        .collect();
    let iters = if std::env::var("DF11_BENCH_QUICK").is_ok() {
        5usize
    } else {
        30
    };

    // Fresh-alloc path (the pre-pool engine): the same sequential
    // decoder, but a new Vec<Bf16> + Vec<f32> for every matrix of every
    // fetch — so the delta below isolates allocation, not decoder choice.
    let t0 = Instant::now();
    for _ in 0..iters {
        for t in &block {
            let w = decompress_sequential(t).unwrap();
            let f: Vec<f32> = w.iter().map(|b| b.to_f32()).collect();
            black_box(f.last().copied());
        }
    }
    let fresh = t0.elapsed().as_secs_f64() / iters as f64;

    // Pooled path: one BF16 staging buffer and one f32 buffer per slot,
    // resized (never reallocated once warm) across fetches.
    let mut staging: Vec<Bf16> = Vec::new();
    let mut widened: Vec<Vec<f32>> = (0..block.len()).map(|_| Vec::new()).collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        for (t, out) in block.iter().zip(widened.iter_mut()) {
            staging.resize(t.num_elements(), Bf16::from_bits(0));
            decompress_sequential_into(t, &mut staging).unwrap();
            out.clear();
            out.extend(staging.iter().map(|b| b.to_f32()));
            black_box(out.last().copied());
        }
    }
    let reused = t0.elapsed().as_secs_f64() / iters as f64;

    let mut table = Table::new(&["path", "per-block fetch", "allocs/fetch"]);
    table.row(&[
        "fresh Vec per fetch".into(),
        fmt::seconds(fresh),
        format!("{}", block.len() * 2),
    ]);
    table.row(&[
        "pooled scratch (engine)".into(),
        fmt::seconds(reused),
        "0 (steady state)".into(),
    ]);
    table.print();
    println!(
        "\nscratch reuse: {:.2}x vs fresh allocation over {} matrices/block",
        fresh / reused,
        block.len()
    );
}
