//! Figure 1: Shannon entropy of BF16 components across the model zoo.
//!
//! The paper's motivating measurement: sign ≈ 1 bit, mantissa ≈ 7 bits
//! (both near-incompressible), exponent ≈ 2.6 of 8 bits. We reproduce
//! it on the synthetic weights that stand in for the checkpoints (and
//! in doing so validate the substitution itself — see DESIGN.md).

use dfloat11::bench_harness::Table;
use dfloat11::entropy::ComponentHistograms;
use dfloat11::model::init::generate_weights;
use dfloat11::model::{zoo, WeightSpec};

fn main() {
    println!("# Figure 1 — component entropy of BF16 weights\n");
    let mut table = Table::new(&[
        "model",
        "H(sign)/1",
        "H(exponent)/8",
        "H(mantissa)/7",
        "optimal bits/w",
    ]);
    for cfg in zoo::table1_llms() {
        let mut hist = ComponentHistograms::new();
        // Sample each distinct matrix kind, weighted implicitly by using
        // equal samples (entropy is insensitive to modest reweighting).
        let inv = cfg.weight_inventory();
        let mut seen = std::collections::HashSet::new();
        for spec in &inv {
            let kind = (spec.name.rsplit('.').next().unwrap().to_string(), spec.fan_in);
            if !seen.insert(kind) {
                continue;
            }
            let sample = WeightSpec {
                shape: [1, 64 * 1024],
                ..spec.clone()
            };
            let w = generate_weights(&sample, 21);
            hist.record_weights(&w);
        }
        let e = hist.entropy();
        table.row(&[
            cfg.name.clone(),
            format!("{:.3}", e.sign_bits),
            format!("{:.3}", e.exponent_bits),
            format!("{:.3}", e.mantissa_bits),
            format!("{:.2}", e.optimal_bits_per_weight()),
        ]);
    }
    table.print();
    println!(
        "\npaper: exponent ≈ 2.6 bits across all models (the compressible \
         component); sign/mantissa near their widths. DF11's ~11 effective \
         bits ≈ 1 + 2.6 + 7 + container overhead."
    );
}
