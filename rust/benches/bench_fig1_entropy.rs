//! Figure 1: Shannon entropy of BF16 components across the model zoo.
//!
//! The paper's motivating measurement: sign ≈ 1 bit, mantissa ≈ 7 bits
//! (both near-incompressible), exponent ≈ 2.6 of 8 bits. We reproduce
//! it on the synthetic weights that stand in for the checkpoints (and
//! in doing so validate the substitution itself — see DESIGN.md).
//!
//! A second section closes the loop from bound to codec: an auto
//! [`CodecSelector`] pass over a fully-generated scaled model reports,
//! per tensor, the achieved bits/weight of the winning codec against
//! the measured component entropy — the tracked Shannon-bound gap.
//!
//! Pass `--json PATH` (or set `DF11_BENCH_JSON`) to also write the
//! measurements as `BENCH_fig1.json`.

use dfloat11::bench_harness::json::{write_artifact, Json};
use dfloat11::bench_harness::Table;
use dfloat11::codec::select::{CodecSelector, SelectionPolicy};
use dfloat11::entropy::ComponentHistograms;
use dfloat11::model::init::{generate_model_weights, generate_weights};
use dfloat11::model::{zoo, WeightSpec};

fn main() {
    println!("# Figure 1 — component entropy of BF16 weights\n");
    let mut table = Table::new(&[
        "model",
        "H(sign)/1",
        "H(exponent)/8",
        "H(mantissa)/7",
        "optimal bits/w",
    ]);
    let mut zoo_rows: Vec<Json> = Vec::new();
    for cfg in zoo::table1_llms() {
        let mut hist = ComponentHistograms::new();
        // Sample each distinct matrix kind, weighted implicitly by using
        // equal samples (entropy is insensitive to modest reweighting).
        let inv = cfg.weight_inventory();
        let mut seen = std::collections::HashSet::new();
        for spec in &inv {
            let kind = (spec.name.rsplit('.').next().unwrap().to_string(), spec.fan_in);
            if !seen.insert(kind) {
                continue;
            }
            let sample = WeightSpec {
                shape: [1, 64 * 1024],
                ..spec.clone()
            };
            let w = generate_weights(&sample, 21);
            hist.record_weights(&w);
        }
        let e = hist.entropy();
        zoo_rows.push(
            Json::obj()
                .field("model", Json::str(&cfg.name))
                .field("sign_bits", Json::num(e.sign_bits))
                .field("exponent_bits", Json::num(e.exponent_bits))
                .field("mantissa_bits", Json::num(e.mantissa_bits))
                .field(
                    "optimal_bits_per_weight",
                    Json::num(e.optimal_bits_per_weight()),
                ),
        );
        table.row(&[
            cfg.name.clone(),
            format!("{:.3}", e.sign_bits),
            format!("{:.3}", e.exponent_bits),
            format!("{:.3}", e.mantissa_bits),
            format!("{:.2}", e.optimal_bits_per_weight()),
        ]);
    }
    table.print();
    println!(
        "\npaper: exponent ≈ 2.6 bits across all models (the compressible \
         component); sign/mantissa near their widths. DF11's ~11 effective \
         bits ≈ 1 + 2.6 + 7 + container overhead."
    );

    // Achieved vs optimal: auto-select a codec per tensor on a fully
    // generated scaled model and measure the gap to the Shannon bound.
    println!("\n## Achieved bits vs entropy (auto selection, scaled model)\n");
    let cfg = zoo::llama31_8b().scaled_down(8);
    let weights = generate_model_weights(&cfg, 42);
    let selector = CodecSelector::new(SelectionPolicy::Auto);
    let (_, report) = selector
        .select_model(weights.iter().map(|(spec, w)| {
            (
                spec.group.as_str(),
                spec.name.as_str(),
                &spec.shape[..],
                &w[..],
            )
        }))
        .expect("auto selection");
    let mut gaps = Table::new(&["tensor", "codec", "achieved bits/w", "entropy", "gap"]);
    for t in &report.tensors {
        gaps.row(&[
            t.name.clone(),
            t.codec.label().to_string(),
            format!("{:.3}", t.achieved_bits_per_weight()),
            format!("{:.3}", t.optimal_bits_per_weight),
            format!("{:+.3}", t.gap_bits()),
        ]);
    }
    gaps.print();
    println!(
        "\naggregate: {:.3} bits/w achieved vs {:.3} optimal (gap {:+.3} bits/w, \
         ratio {:.2}%)",
        report.achieved_bits_per_weight(),
        report.optimal_bits_per_weight(),
        report.aggregate_gap_bits(),
        report.ratio_percent()
    );

    let artifact = Json::obj()
        .field("bench", Json::str("fig1_entropy"))
        .field("model", Json::str(&cfg.name))
        .field("zoo_entropy", Json::Array(zoo_rows))
        .field("selection", report.to_json());
    match write_artifact("fig1", &artifact) {
        Ok(Some(path)) => println!("wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("artifact write failed: {e}"),
    }
}
