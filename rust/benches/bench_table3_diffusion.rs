//! Table 3: diffusion transformers — peak memory + generation time.
//!
//! Measured: DF11 ratio + decompression throughput on a real DiT block's
//! synthetic weights. Estimated (device model): peak memory and
//! 1024x1024 generation time on the paper's A5000.

use dfloat11::bench_harness::{fmt, Bencher, Table};
use dfloat11::gpu_sim::timing::TimingModel;
use dfloat11::gpu_sim::Device;
use dfloat11::model::diffusion::DiffusionConfig;
use dfloat11::model::init::generate_weights;
use dfloat11::Df11Tensor;

/// Paper Table 3: (model, bf16 peak GB, df11 peak GB, bf16 s, df11 s).
const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    ("Stable Diffusion 3.5 Large", 16.44, 11.78, 66.36, 69.08),
    ("FLUX.1 dev", 23.15, 16.72, 74.41, 78.53),
];

fn main() {
    println!("# Table 3 — diffusion transformers (A5000, 1024x1024)\n");
    let device = Device::a5000();
    let timing = TimingModel::new(device.clone());
    let bench = Bencher::from_env();

    let mut table = Table::new(&[
        "model",
        "measured ratio %",
        "bf16 peak (est)",
        "df11 peak (est)",
        "bf16 gen (est)",
        "df11 gen (est)",
        "paper peaks",
        "paper times",
    ]);

    for (cfg, &(_, p_bf16_gb, p_df11_gb, p_bf16_s, p_df11_s)) in [
        DiffusionConfig::sd35_large(),
        DiffusionConfig::flux1_dev(),
    ]
    .iter()
    .zip(PAPER)
    {
        // Measure the ratio on one block's real (synthetic) weights.
        let mut orig = 0u64;
        let mut comp = 0u64;
        for spec in cfg.weight_inventory().iter().take(7) {
            let mut sample = spec.clone();
            let cap = 1 << 20;
            if sample.numel() > cap {
                sample.shape = [1, cap];
            }
            let w = generate_weights(&sample, 11);
            let t = Df11Tensor::compress(&w).unwrap();
            assert_eq!(t.decompress().unwrap(), w);
            let scale = spec.numel() as f64 / sample.numel() as f64;
            orig += (t.original_bytes() as f64 * scale) as u64;
            comp += (t.compressed_bytes() as f64 * scale) as u64;
        }
        let ratio = comp as f64 / orig as f64;

        let act = 2u64 * (cfg.latent_tokens * cfg.d_ff) as u64 * 2 * 4;
        let bf16_peak = cfg.total_bf16_bytes() + act;
        let df11_peak = (cfg.bf16_bytes() as f64 * ratio) as u64
            + cfg.uncompressed_bytes
            + act
            + cfg.bf16_bytes() / cfg.n_blocks() as u64;

        let step_compute = cfg.flops_per_step() / (device.bf16_flops * 0.45);
        let decomp = timing.df11_decompress_time(
            cfg.num_params(),
            (cfg.num_params() as f64 * 2.0 * ratio) as u64,
            cfg.num_params() / 2048 + 1,
        );
        let bf16_time = cfg.denoise_steps as f64 * step_compute;
        let df11_time = cfg.denoise_steps as f64 * (step_compute + decomp);

        table.row(&[
            cfg.name.clone(),
            format!("{:.2}", 100.0 * ratio),
            fmt::bytes(bf16_peak),
            fmt::bytes(df11_peak),
            format!("{bf16_time:.1} s"),
            format!("{df11_time:.1} s"),
            format!("{p_bf16_gb:.1}->{p_df11_gb:.1} GB"),
            format!("{p_bf16_s:.1}->{p_df11_s:.1} s"),
        ]);
    }
    table.print();

    // Measured decompression throughput of one DiT matrix (what the
    // latency delta is made of).
    let spec = DiffusionConfig::sd35_large().weight_inventory()[0].clone();
    let w = generate_weights(&spec, 12);
    let t = Df11Tensor::compress(&w).unwrap();
    let mut out = vec![dfloat11::Bf16::from_bits(0); w.len()];
    let r = bench.bench("decompress q_proj", || t.decompress_into(&mut out).unwrap());
    println!(
        "\nmeasured: one {}x{} DiT matrix decompresses at {} (CPU sim)",
        spec.shape[0],
        spec.shape[1],
        fmt::throughput_bps(t.original_bytes() as f64 / r.mean)
    );
    println!("paper shape: ~28% peak-memory cut, single-digit-% latency increase — preserved.");
}
