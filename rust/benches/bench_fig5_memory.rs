//! Figure 5: GPU memory vs generated tokens; OOM points.
//!
//! DF11's weight savings become KV-cache headroom: at batch 1, how many
//! tokens fit before OOM? Uses the KV manager + HBM accountant with a
//! PyTorch-like framework overhead model.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::gpu_sim::{Device, HbmAllocator, MemoryCategory};
use dfloat11::kvcache::KvCacheManager;
use dfloat11::model::zoo;
use dfloat11::offload::DF11_RATIO;

/// Framework overhead: CUDA context + allocator slack + activation
/// buffers (the paper's HF/torch stack reserves several GB).
fn overhead(_device: &Device, model_bytes: u64) -> u64 {
    2 * (1 << 30) + model_bytes / 16
}

fn main() {
    println!("# Figure 5 — memory growth with generated tokens (batch 1)\n");
    // Model/GPU pairs where BF16 barely fits — the paper's setting.
    let cases = [
        (zoo::llama31_8b(), Device::a5000()),     // 16 GB on 24 GB
        (zoo::qwen3_14b(), Device::a100_40g()),   // 29.5 GB on 40 GB
        (zoo::mistral_small3(), Device::rtx8000()), // 47 GB on 48 GB
        (zoo::qwq_32b(), Device::a100_80g()),     // 65.5 GB on 80 GB
    ];

    let mut table = Table::new(&[
        "model",
        "device",
        "bf16 free",
        "df11 free",
        "bf16 max tokens",
        "df11 max tokens",
        "gain",
    ]);
    for (cfg, device) in &cases {
        let mgr = KvCacheManager::new(cfg, 16);
        let bf16_w = cfg.bf16_bytes();
        let df11_w = (bf16_w as f64 * DF11_RATIO) as u64;
        let free = |w: u64| {
            device
                .hbm_bytes
                .saturating_sub(w)
                .saturating_sub(overhead(device, w))
        };
        let (f_bf16, f_df11) = (free(bf16_w), free(df11_w));
        let t_bf16 = mgr.max_tokens_within(f_bf16, 1);
        let t_df11 = mgr.max_tokens_within(f_df11, 1);
        table.row(&[
            cfg.name.clone(),
            device.name.to_string(),
            fmt::bytes(f_bf16),
            fmt::bytes(f_df11),
            if t_bf16 == 0 { "O.O.M.".into() } else { t_bf16.to_string() },
            t_df11.to_string(),
            if t_bf16 == 0 {
                "inf (bf16 OOM at load)".to_string()
            } else {
                format!("{:.2}x", t_df11 as f64 / t_bf16 as f64)
            },
        ]);
    }
    table.print();

    // Live allocator run: memory as a function of token count for one
    // pair (the Figure 5 curve, numerically).
    println!("\n## Memory vs tokens, Llama-8B on A5000 (live allocator)\n");
    let cfg = zoo::llama31_8b();
    let device = Device::a5000();
    let mut curve = Table::new(&["tokens", "bf16 used", "df11 used"]);
    let run = |ratio: f64| -> Vec<(u64, u64)> {
        let mut hbm = HbmAllocator::new(device.clone());
        let w = (cfg.bf16_bytes() as f64 * ratio) as u64;
        hbm.alloc(MemoryCategory::Weights, w).unwrap();
        hbm.alloc(MemoryCategory::Overhead, overhead(&device, w)).unwrap();
        let mut mgr = KvCacheManager::new(&cfg, 16);
        mgr.add_sequence(1).unwrap();
        let mut pts = Vec::new();
        let mut tokens = 0u64;
        loop {
            pts.push((tokens, hbm.used()));
            if mgr.extend(&mut hbm, 1, 4096).is_err() {
                break;
            }
            tokens += 4096;
        }
        pts
    };
    let bf16_pts = run(1.0);
    let df11_pts = run(DF11_RATIO);
    let max_len = bf16_pts.len().max(df11_pts.len());
    for i in (0..max_len).step_by(2) {
        let b = bf16_pts.get(i);
        let d = df11_pts.get(i);
        curve.row(&[
            format!("{}", i as u64 * 4096),
            b.map(|(_, u)| fmt::bytes(*u)).unwrap_or_else(|| "O.O.M.".into()),
            d.map(|(_, u)| fmt::bytes(*u)).unwrap_or_else(|| "-".into()),
        ]);
    }
    curve.print();
    println!(
        "\npaper: 5.70–14.86x more tokens before OOM; gain grows as BF16 \
         weights approach HBM capacity (Mistral-Small-3-on-48GB row)."
    );
}
