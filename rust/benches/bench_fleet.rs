//! Fleet goodput: DF11 vs BF16 replicas under one per-replica HBM
//! budget.
//!
//! The fleet-level version of the paper's freed-memory story: at equal
//! replica count and an identical per-replica HBM budget, DF11's
//! smaller resident weights leave more KV pages per replica, so the
//! fleet *schedules* long-context requests a BF16 fleet must reject as
//! unschedulable — and therefore sustains strictly higher goodput
//! (completed tokens per second) on a mixed open-loop workload. Both
//! router policies are exercised; the goodput-vs-offered-load curve is
//! swept per source.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{
    goodput_sweep, Engine, Fleet, FleetReport, LeastLoaded, RejectReason, Request, RoundRobin,
    RouterPolicy, ServeConfig, WeightMode,
};
use dfloat11::error::Result;
use dfloat11::model::ModelConfig;

const PAGE_TOKENS: u64 = 16;
const REPLICAS: usize = 2;
const SLOTS: usize = 4;
const LONG_NEW: usize = 39; // worst case 2 + 39 - 1 = 40 tokens -> 3 pages
const SHORT_NEW: usize = 6; // worst case 2 + 6 - 1 = 7 tokens  -> 1 page

fn bench_config() -> ModelConfig {
    // Large enough that DF11's compression gap dwarfs per-tensor
    // overheads, small enough to serve in milliseconds.
    ModelConfig {
        name: "bench-fleet".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 256,
        max_seq_len: 64,
        tie_embeddings: false,
    }
}

fn router_by(name: &str) -> Box<dyn RouterPolicy> {
    match name {
        "round-robin" => Box::new(RoundRobin::new()),
        "least-loaded" => Box::new(LeastLoaded::new()),
        other => panic!("unknown router {other}"),
    }
}

fn fleet_for(
    cfg: &ModelConfig,
    mode: &WeightMode,
    budget: u64,
    router: &str,
) -> Result<Fleet<Engine>> {
    let mut engines = Vec::with_capacity(REPLICAS);
    for _ in 0..REPLICAS {
        engines.push(Engine::build(cfg, 7, mode.clone())?);
    }
    let config = ServeConfig::new()
        .slots(SLOTS)
        .replicas(REPLICAS)
        .hbm_budget(budget)
        .page_tokens(PAGE_TOKENS);
    Fleet::new(engines, config, router_by(router))
}

/// Alternating long/short requests arriving open-loop over `span`
/// seconds. Longs need 3 KV pages (unschedulable on a 2-page BF16
/// replica); shorts need 1.
fn mixed_workload(n: usize, span: f64) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let max_new = if i % 2 == 0 { LONG_NEW } else { SHORT_NEW };
            Request::new(vec![(i % 50 + 1) as u32, 2], max_new)
                .with_arrival(i as f64 * span / n as f64)
        })
        .collect()
}

fn run_fleet(
    cfg: &ModelConfig,
    mode: &WeightMode,
    budget: u64,
    router: &str,
    workload: &[Request],
) -> FleetReport {
    let mut fleet = fleet_for(cfg, mode, budget, router).unwrap();
    for r in workload {
        let at = r.arrival;
        fleet.submit_at(r.clone(), at).unwrap();
    }
    fleet.drain().unwrap()
}

fn main() {
    let cfg = bench_config();
    let bf16_resident = Engine::build(&cfg, 7, WeightMode::Bf16Resident)
        .unwrap()
        .resident_weight_bytes();
    let df11_resident = Engine::build(&cfg, 7, WeightMode::Df11)
        .unwrap()
        .resident_weight_bytes();
    let page = PAGE_TOKENS * cfg.kv_bytes_per_token();
    // One per-replica budget for both fleets: BF16 weights + exactly 2
    // KV pages. DF11's freed weight bytes become extra pages.
    let budget = bf16_resident + 2 * page;
    let df11_pages = budget.saturating_sub(df11_resident) / page;
    assert!(
        df11_pages >= 3,
        "df11 must free at least one long request's worth of pages \
         (got {df11_pages}); grow the config"
    );
    println!("# Fleet goodput: DF11 vs BF16 at equal replica count\n");
    println!(
        "model {} ({} params), {REPLICAS} replicas x {SLOTS} slots, per-replica HBM {}",
        cfg.name,
        cfg.num_params(),
        fmt::bytes(budget)
    );
    println!(
        "KV pages per replica: bf16 {} (resident {}), df11 {} (resident {})",
        budget.saturating_sub(bf16_resident) / page,
        fmt::bytes(bf16_resident),
        df11_pages,
        fmt::bytes(df11_resident)
    );
    println!(
        "workload: alternating long ({LONG_NEW} new -> 3 pages) and short \
         ({SHORT_NEW} new -> 1 page) requests\n"
    );

    // --- Goodput table, both router policies ---------------------------
    println!("## Goodput at equal replica count (both router policies)\n");
    let workload = mixed_workload(12, 0.25);
    let longs = workload.iter().filter(|r| r.max_new_tokens == LONG_NEW).count();
    let mut table = Table::new(&[
        "source",
        "router",
        "completed",
        "rejected",
        "tokens",
        "seconds",
        "goodput tok/s",
    ]);
    let mut verdicts = Vec::new();
    for router in ["round-robin", "least-loaded"] {
        let mut goodputs = Vec::new();
        for (src, mode) in [
            ("bf16", WeightMode::Bf16Resident),
            ("df11", WeightMode::Df11),
        ] {
            let r = run_fleet(&cfg, &mode, budget, router, &workload);
            assert_eq!(
                r.responses.len() + r.rejections.len(),
                workload.len(),
                "every request accounted for"
            );
            if src == "bf16" {
                // Page math, not luck: every long exceeds BF16's whole
                // per-replica budget.
                assert_eq!(r.rejections.len(), longs, "bf16 rejects exactly the longs");
                assert!(r
                    .rejections
                    .iter()
                    .all(|rej| rej.reason == RejectReason::Unschedulable));
            } else {
                assert!(r.rejections.is_empty(), "df11 schedules everything");
            }
            table.row(&[
                src.to_string(),
                router.to_string(),
                format!("{}", r.responses.len()),
                format!("{}", r.rejections.len()),
                format!("{}", r.total_tokens),
                fmt::seconds(r.total_seconds),
                format!("{:.1}", r.goodput()),
            ]);
            goodputs.push(r.goodput());
        }
        let (bf16_gp, df11_gp) = (goodputs[0], goodputs[1]);
        assert!(
            df11_gp > bf16_gp,
            "df11 goodput {df11_gp:.1} must beat bf16 {bf16_gp:.1} under router {router}"
        );
        verdicts.push((router, df11_gp / bf16_gp.max(1e-12)));
    }
    table.print();
    println!();
    for (router, gain) in &verdicts {
        println!("{router}: df11 goodput {gain:.2}x bf16 at equal replicas [ok]");
    }

    // --- Goodput vs offered load ---------------------------------------
    println!("\n## Goodput vs offered load (round-robin router)\n");
    let base = mixed_workload(12, 0.0);
    let loads = [25.0, 50.0, 100.0, 200.0];
    let mut curves = Vec::new();
    for mode in [WeightMode::Bf16Resident, WeightMode::Df11] {
        let curve = goodput_sweep(
            || fleet_for(&cfg, &mode, budget, "round-robin"),
            &base,
            &loads,
        )
        .unwrap();
        curves.push(curve);
    }
    let mut table = Table::new(&[
        "offered rps",
        "bf16 done/rej",
        "bf16 tok/s",
        "df11 done/rej",
        "df11 tok/s",
    ]);
    for (b, d) in curves[0].iter().zip(&curves[1]) {
        assert!(
            d.goodput_tps > b.goodput_tps,
            "df11 goodput must beat bf16 at {} rps ({:.1} vs {:.1})",
            b.offered_rps,
            d.goodput_tps,
            b.goodput_tps
        );
        table.row(&[
            format!("{:.0}", b.offered_rps),
            format!("{}/{}", b.completed, b.rejected),
            format!("{:.1}", b.goodput_tps),
            format!("{}/{}", d.completed, d.rejected),
            format!("{:.1}", d.goodput_tps),
        ]);
    }
    table.print();
    println!(
        "\ndf11 > bf16 at every offered load: freed weight memory is \
         schedulable KV capacity [ok]"
    );
}
