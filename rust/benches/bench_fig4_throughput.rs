//! Figure 4: token-decoding throughput/latency, DF11 vs BF16+offload.
//!
//! Paper setting: the BF16 model does not fit the GPU, so layers are
//! offloaded to CPU RAM and stream over PCIe every step; DF11 fits
//! entirely on-device. Two row families here:
//! * **measured** — the executable engine at reduced scale, all three
//!   modes, real work + simulated PCIe time on the serving clock;
//! * **estimated** — the paper's exact model/GPU pairs through the
//!   device timing model.

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{Engine, Request, SchedulerConfig, Server, WeightMode};
use dfloat11::gpu_sim::{Device, TransferModel};
use dfloat11::model::zoo;
use dfloat11::offload::{place, throughput, PlacementMode};

/// Measure the sequential DF11 decode rate (output bytes/s) on a
/// representative tensor.
fn measure_decode_rate() -> f64 {
    use dfloat11::dfloat11::decompress::decompress_sequential_into;
    use dfloat11::model::init::generate_weights;
    use dfloat11::model::WeightSpec;
    let spec = WeightSpec {
        name: "calib".into(),
        group: "calib".into(),
        shape: [1, 1 << 20],
        fan_in: 4096,
    };
    let w = generate_weights(&spec, 1);
    let t = dfloat11::Df11Tensor::compress(&w).unwrap();
    let mut out = vec![dfloat11::Bf16::from_bits(0); w.len()];
    let t0 = std::time::Instant::now();
    let iters = 5;
    for _ in 0..iters {
        decompress_sequential_into(&t, &mut out).unwrap();
    }
    (w.len() as f64 * 2.0 * iters as f64) / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("# Figure 4 — decoding throughput: DF11 vs BF16 + CPU offload\n");

    // --- Measured at reduced scale ---
    // Calibration: on the paper's testbed, on-GPU DF11 decompression
    // runs ~8x faster than PCIe can deliver BF16 (200 GB/s vs 25 GB/s).
    // Our substrate decodes on a CPU, so the simulated PCIe bandwidth is
    // scaled to preserve that testbed ratio — otherwise the scaled-down
    // workload would make transfers unrealistically free.
    println!("## Measured (scaled Llama-8B/8, CPU engine + ratio-calibrated PCIe)\n");
    let mut cfg = zoo::llama31_8b().scaled_down(8);
    // Byte-level vocab so transformer blocks dominate the parameter
    // budget, as they do at full scale.
    cfg.vocab_size = 256;
    let decode_rate = measure_decode_rate();
    let calibrated = TransferModel {
        bandwidth: decode_rate / 8.0,
        latency: 10e-6,
    };
    println!(
        "measured CPU decode rate {} -> simulated PCIe {}\n",
        fmt::throughput_bps(decode_rate),
        fmt::throughput_bps(calibrated.bandwidth)
    );
    let mut table = Table::new(&["batch", "mode", "tok/s", "speedup vs offload"]);
    for batch in [1usize, 4, 8] {
        let mut rows: Vec<(String, f64)> = Vec::new();
        for (label, mode) in [
            (
                "BF16+offload",
                WeightMode::OffloadBf16 {
                    resident_layers: 1,
                    transfer: calibrated.clone(),
                },
            ),
            ("DF11", WeightMode::Df11),
        ] {
            let engine = Engine::build(&cfg, 3, mode).unwrap();
            let mut server = Server::new(engine, SchedulerConfig::static_batch(batch));
            for i in 0..batch {
                server
                    .submit(Request::new(vec![(i % 60 + 1) as u32, 2], 16))
                    .unwrap();
            }
            let report = server.drain().unwrap();
            rows.push((label.to_string(), report.tokens_per_second()));
        }
        let offload_tps = rows[0].1;
        for (label, tps) in rows {
            table.row(&[
                batch.to_string(),
                label.clone(),
                format!("{tps:.2}"),
                format!("{:.2}x", tps / offload_tps),
            ]);
        }
    }
    table.print();

    // --- Paper-scale estimates ---
    println!("\n## Estimated at paper scale (device model)\n");
    let cases = [
        (zoo::llama33_70b(), Device::a100_80g()), // 141 GB on 80 GB
        (zoo::qwq_32b(), Device::a100_40g()),     // 65 GB on 40 GB
        (zoo::mistral_small3(), Device::a5000()), // 47 GB on 24 GB
    ];
    let mut table = Table::new(&[
        "model", "device", "batch", "offload tok/s", "df11 tok/s", "speedup",
    ]);
    for (model, device) in cases {
        let off = place(&model, &device, PlacementMode::Bf16Offload, 1 << 30);
        // DF11 on the smallest device that fits it (paper uses larger
        // GPUs / more GPUs when needed; speedup is against offload).
        let df11_dev = if (model.bf16_bytes() as f64 * 0.679) < device.hbm_bytes as f64 * 0.9 {
            device.clone()
        } else {
            Device::a100_80g()
        };
        let df11 = place(&model, &df11_dev, PlacementMode::Df11, 1 << 30);
        for batch in [1u64, 8, 32] {
            let t_off = throughput(&model, &device, &off, batch);
            let t_df11 = throughput(&model, &df11_dev, &df11, batch);
            table.row(&[
                model.name.clone(),
                device.name.to_string(),
                batch.to_string(),
                format!("{t_off:.2}"),
                format!("{t_df11:.2}"),
                format!("{:.1}x", t_df11 / t_off),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper: 2.31–46.24x higher throughput for DF11 over BF16+offload; \
         the gap widens with the offloaded fraction ({} of PCIe per step).",
        fmt::throughput_bps(25e9)
    );
}
