//! Table 4: compression time per transformer block (single thread).
//!
//! Measured at several scaled block sizes, then extrapolated by the
//! measured bytes/s rate to the paper's per-block parameter counts.

use dfloat11::bench_harness::{fmt, Bencher, Table};
use dfloat11::model::init::generate_weights;
use dfloat11::model::{zoo, WeightSpec};
use dfloat11::Df11Tensor;

/// Paper Table 4: (model, seconds per block, 1 CPU thread).
const PAPER: &[(&str, f64)] = &[
    ("Llama 3.1 8B Instruct", 191.0),
    ("Llama 3.3 70B Instruct", 547.0),
    ("Llama 3.1 405B Instruct", 2133.0),
];

fn main() {
    println!("# Table 4 — compression time per transformer block\n");
    let bench = Bencher::from_env();

    // Measure compression rate on increasing tensor sizes.
    let mut rate_table = Table::new(&["tensor elems", "compress time", "rate"]);
    let mut best_rate = 0.0f64;
    for log2 in [16u32, 18, 20, 22] {
        let n = 1usize << log2;
        let spec = WeightSpec {
            name: format!("bench.{log2}"),
            group: "bench".into(),
            shape: [1, n],
            fan_in: 4096,
        };
        let w = generate_weights(&spec, 5);
        let r = bench.bench(&format!("compress 2^{log2}"), || {
            Df11Tensor::compress(&w).unwrap()
        });
        let rate = (n as f64 * 2.0) / r.mean;
        best_rate = best_rate.max(rate);
        rate_table.row(&[
            format!("2^{log2}"),
            fmt::seconds(r.mean),
            fmt::throughput_bps(rate),
        ]);
    }
    rate_table.print();

    // Extrapolate to the paper's block sizes.
    println!("\n## Extrapolated per-block compression time (vs paper's 1-thread numbers)\n");
    let mut table = Table::new(&[
        "model",
        "params/block",
        "ours (est, 1 thread)",
        "paper (1 thread)",
    ]);
    for (cfg, &(_, paper_s)) in [zoo::llama31_8b(), zoo::llama33_70b(), zoo::llama31_405b()]
        .iter()
        .zip(PAPER)
    {
        let bytes = cfg.params_per_block() as f64 * 2.0;
        table.row(&[
            cfg.name.clone(),
            format!("{:.1}M", cfg.params_per_block() as f64 / 1e6),
            format!("{:.0} s", bytes / best_rate),
            format!("{paper_s:.0} s"),
        ]);
    }
    table.print();
    println!(
        "\nshape check: compression is a one-time preprocessing cost that scales \
         linearly with block size and parallelizes across blocks (paper Appendix F)."
    );
}
