//! Continuous vs static batching under staggered arrivals.
//!
//! The serving side of the paper's claims: DF11's decode path is only
//! worth shipping if end-to-end scheduler behavior holds up (ZipServ's
//! framing). Two comparisons here:
//!
//! 1. **Policy**: at the same slot count, continuous batching must
//!    deliver lower mean queue delay and TTFT than static round-based
//!    batching on a head-of-line-blocking workload.
//! 2. **Memory → slots**: under the same simulated HBM budget, the
//!    DF11 engine's smaller resident weights leave more KV pages, so
//!    it sustains more concurrent decode slots than BF16 (Figure 5's
//!    freed-memory story as admission behavior).

use dfloat11::bench_harness::{fmt, Table};
use dfloat11::coordinator::{
    trace, Engine, Request, SchedPolicy, SchedulerConfig, ServeReport, Server, WeightMode,
};
use dfloat11::model::ModelConfig;

fn bench_config() -> ModelConfig {
    // Large enough that DF11's compression gap dwarfs per-tensor
    // overheads, small enough to serve in milliseconds.
    ModelConfig {
        name: "bench-serving".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 256,
        max_seq_len: 64,
        tie_embeddings: false,
    }
}

fn run(
    cfg: &ModelConfig,
    mode: WeightMode,
    policy: SchedPolicy,
    slots: usize,
    hbm_bytes: Option<u64>,
    workload: &[Request],
) -> ServeReport {
    let engine = Engine::build(cfg, 7, mode).unwrap();
    let mut server = Server::new(
        engine,
        SchedulerConfig {
            max_batch: slots,
            policy,
            hbm_bytes,
            page_tokens: 16,
            ..SchedulerConfig::default()
        },
    );
    for r in workload {
        let at = r.arrival;
        server.submit_at(r.clone(), at).unwrap();
    }
    server.drain().unwrap()
}

fn main() {
    let cfg = bench_config();
    println!("# Continuous batching under staggered arrivals\n");
    println!(
        "model {} ({} params), staggered open-loop arrivals\n",
        cfg.name,
        cfg.num_params()
    );

    // Head-of-line workload: one long generation up front, short
    // requests trickling in behind it — the case static rounds serve
    // worst. Budgets cycle long/short; arrivals are closely staggered.
    let mut workload = vec![Request::new(vec![1, 2, 3], 24)];
    workload.extend(trace::staggered(9, 1e-4, 2, &[2, 3, 16, 2]));

    println!("## Policy comparison (same engine, same slots)\n");
    let mut table = Table::new(&[
        "source",
        "sched",
        "queue delay mean",
        "ttft mean",
        "tpot mean",
        "tok/s",
        "occupancy mean/peak",
    ]);
    let mut policy_gaps: Vec<(String, f64, f64)> = Vec::new();
    for (src, mode) in [
        ("bf16", WeightMode::Bf16Resident),
        ("df11", WeightMode::Df11),
    ] {
        let mut per_policy = Vec::new();
        for (label, policy) in [
            ("static", SchedPolicy::Static),
            ("continuous", SchedPolicy::Continuous),
        ] {
            let r = run(&cfg, mode.clone(), policy, 2, None, &workload);
            assert_eq!(r.responses.len(), workload.len(), "all requests complete");
            table.row(&[
                src.to_string(),
                label.to_string(),
                fmt::seconds(r.queue_delay.mean()),
                fmt::seconds(r.ttft.mean()),
                fmt::seconds(r.tpot.mean()),
                format!("{:.1}", r.tokens_per_second()),
                format!("{:.2}/{}", r.occupancy.mean(), r.occupancy.peak),
            ]);
            per_policy.push(r);
        }
        let (stat, cont) = (&per_policy[0], &per_policy[1]);
        policy_gaps.push((
            src.to_string(),
            stat.queue_delay.mean() / cont.queue_delay.mean().max(1e-12),
            stat.ttft.mean() / cont.ttft.mean().max(1e-12),
        ));
    }
    table.print();
    println!();
    for (src, qd, ttft) in &policy_gaps {
        let ok = *qd > 1.0 && *ttft > 1.0;
        println!(
            "{src}: continuous vs static -> queue delay {qd:.2}x lower, ttft {ttft:.2}x lower {}",
            if ok { "[ok]" } else { "[REGRESSION]" }
        );
    }

    // --- Freed memory becomes concurrent slots -------------------------
    println!("\n## Same HBM budget, continuous scheduling: slots sustained\n");
    // Budget = BF16 resident weights + a handful of KV pages, so BF16
    // serializes while DF11's freed weight bytes admit concurrency.
    let bf16_resident = Engine::build(&cfg, 7, WeightMode::Bf16Resident)
        .unwrap()
        .resident_weight_bytes();
    let df11_resident = Engine::build(&cfg, 7, WeightMode::Df11)
        .unwrap()
        .resident_weight_bytes();
    let page = 16 * cfg.kv_bytes_per_token();
    let budget = bf16_resident + 2 * page;
    let slot_load: Vec<Request> = (0..6)
        .map(|i| Request::new(vec![i as u32 + 1, 2], 8))
        .collect();
    let mut table = Table::new(&[
        "source",
        "resident weights",
        "free KV pages",
        "occupancy mean/peak",
        "tok/s",
    ]);
    let mut peaks = Vec::new();
    for (src, mode, resident) in [
        ("bf16", WeightMode::Bf16Resident, bf16_resident),
        ("df11", WeightMode::Df11, df11_resident),
    ] {
        let r = run(
            &cfg,
            mode,
            SchedPolicy::Continuous,
            6,
            Some(budget),
            &slot_load,
        );
        assert_eq!(r.responses.len(), slot_load.len(), "all requests complete");
        table.row(&[
            src.to_string(),
            fmt::bytes(resident),
            format!("{}", budget.saturating_sub(resident) / page),
            format!("{:.2}/{}", r.occupancy.mean(), r.occupancy.peak),
            format!("{:.1}", r.tokens_per_second()),
        ]);
        peaks.push((src, r.occupancy.peak));
    }
    table.print();
    println!();
    let (bf16_peak, df11_peak) = (peaks[0].1, peaks[1].1);
    println!(
        "df11 sustains {df11_peak} concurrent slots vs bf16 {bf16_peak} under {} HBM {}",
        fmt::bytes(budget),
        if df11_peak >= bf16_peak { "[ok]" } else { "[REGRESSION]" }
    );
}
