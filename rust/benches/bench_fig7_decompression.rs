//! Figure 7: decompression throughput/latency vs matrix size —
//! DF11 kernel vs CPU->GPU transfer vs nvCOMP-style ANS — plus the
//! CPU parallel two-phase pipeline's thread-count sweep.
//!
//! Fully measured on this host (the substrate is the CPU simulator):
//! * DF11 two-phase kernel (Algorithm 1 fidelity path),
//! * DF11 sequential decoder (optimized single-stream hot path),
//! * DF11 parallel pipeline at 1/2/4/8 worker threads, with per-phase
//!   timing and the sequential-vs-parallel speedup,
//! * rANS decode (the nvCOMP ANS stand-in),
//! plus the *modelled* PCIe transfer time for the same matrices, and
//! the analytic A100 projection of the DF11 kernel.

//! Pass `--json PATH` (or set `DF11_BENCH_JSON`) to also write the
//! measurements as `BENCH_fig7.json`.

use dfloat11::ans::{compress_bf16_generic, rans_decode};
use dfloat11::bench_harness::json::{write_artifact, Json};
use dfloat11::bench_harness::{fmt, Bencher, Table};
use dfloat11::bf16::Bf16;
use dfloat11::coordinator::{
    BlockCacheMode, Engine, Request, SchedulerConfig, Server, WeightMode,
};
use dfloat11::crc32::Hasher;
use dfloat11::dfloat11::decompress::{
    decompress_sequential_hierarchical_into, decompress_sequential_into,
};
use dfloat11::dfloat11::parallel::{decompress_parallel_into, decompress_pooled_into};
use dfloat11::gpu_sim::timing::TimingModel;
use dfloat11::gpu_sim::{Device, TransferModel};
use dfloat11::model::init::generate_weights;
use dfloat11::model::{ModelConfig, WeightSpec};
use dfloat11::{Df11Tensor, WorkerPool};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// CRC-32 over a decoded buffer's BF16 bits (little-endian).
fn bits_crc(ws: &[Bf16]) -> u32 {
    let mut h = Hasher::new();
    for w in ws {
        h.update(&w.to_bits().to_le_bytes());
    }
    h.finalize()
}

/// Token digest in request-id order, like the CLI's `tokens-crc32`.
fn tokens_crc(report: &dfloat11::coordinator::ServeReport) -> u32 {
    let mut responses: Vec<_> = report.responses.iter().collect();
    responses.sort_by_key(|r| r.id);
    let mut h = Hasher::new();
    for r in &responses {
        h.update(&r.id.to_le_bytes());
        for t in &r.tokens {
            h.update(&t.to_le_bytes());
        }
    }
    h.finalize()
}

fn main() {
    println!("# Figure 7 — decompression vs transfer vs ANS (sliced lm_head matrices)\n");
    let bench = Bencher::from_env();
    let transfer = TransferModel::for_device(&Device::a100_40g());
    let a100 = TimingModel::new(Device::a100_40g());

    let mut table = Table::new(&[
        "elements",
        "df11 kernel",
        "df11 sequential",
        "rANS decode",
        "PCIe xfer (model)",
        "A100 est (df11)",
        "A100-df11 vs PCIe",
    ]);
    let mut sweep = Table::new(&[
        "elements",
        "threads",
        "parallel thpt",
        "vs sequential",
        "phase1 + phase2",
    ]);
    let mut size_rows: Vec<Json> = Vec::new();
    let mut sweep_rows: Vec<Json> = Vec::new();

    for log2 in [16u32, 18, 20, 22] {
        let n = 1usize << log2;
        let spec = WeightSpec {
            name: format!("lm_head.slice{log2}"),
            group: "lm_head".into(),
            shape: [1, n],
            fan_in: 4096,
        };
        let w = generate_weights(&spec, 17);
        let bf16_bytes = (n * 2) as u64;

        // DF11 two-phase kernel.
        let t = Df11Tensor::compress(&w).unwrap();
        let mut out = vec![Bf16::from_bits(0); n];
        let r_kernel = bench.bench("kernel", || t.decompress_into(&mut out).unwrap());
        assert_eq!(out, w);

        // DF11 sequential hot path.
        let r_seq = bench.bench("seq", || decompress_sequential_into(&t, &mut out).unwrap());

        // DF11 parallel pipeline: thread sweep with per-phase timing.
        for threads in THREAD_SWEEP {
            let r_par = bench.bench("par", || {
                decompress_parallel_into(&t, &mut out, threads).unwrap()
            });
            assert_eq!(out, w, "parallel decode must stay bit-exact");
            let stats = decompress_parallel_into(&t, &mut out, threads).unwrap();
            sweep.row(&[
                format!("2^{log2}"),
                threads.to_string(),
                fmt::throughput_bps(bf16_bytes as f64 / r_par.mean),
                format!("{:.2}x", r_seq.mean / r_par.mean),
                fmt::phase_split(stats.phase1_seconds, stats.phase2_seconds),
            ]);
            sweep_rows.push(
                Json::obj()
                    .field("log2_elements", Json::int(log2 as u64))
                    .field("threads", Json::int(threads as u64))
                    .field("parallel_s", Json::num(r_par.mean))
                    .field("vs_sequential", Json::num(r_seq.mean / r_par.mean))
                    .field("phase1_s", Json::num(stats.phase1_seconds))
                    .field("phase2_s", Json::num(stats.phase2_seconds)),
            );
        }

        // rANS baseline.
        let (model, enc) = compress_bf16_generic(&w).unwrap();
        let r_ans = bench.bench("rans", || rans_decode(&model, &enc, n * 2).unwrap());

        // Modelled PCIe transfer of the BF16 matrix.
        let t_pcie = transfer.transfer_time(bf16_bytes);

        // Analytic A100 estimate for the DF11 kernel.
        let blocks = (t.aux().num_blocks as u64).max(1);
        let a100_thpt = a100.df11_decompress_throughput(n as u64, t.compressed_bytes(), blocks);

        let thpt = |mean: f64| fmt::throughput_bps(bf16_bytes as f64 / mean);
        let pcie_thpt = bf16_bytes as f64 / t_pcie;
        table.row(&[
            format!("2^{log2}"),
            thpt(r_kernel.mean),
            thpt(r_seq.mean),
            thpt(r_ans.mean),
            thpt(t_pcie),
            fmt::throughput_bps(a100_thpt),
            format!("{:.1}x", a100_thpt / pcie_thpt),
        ]);
        size_rows.push(
            Json::obj()
                .field("log2_elements", Json::int(log2 as u64))
                .field("kernel_s", Json::num(r_kernel.mean))
                .field("sequential_s", Json::num(r_seq.mean))
                .field("rans_s", Json::num(r_ans.mean))
                .field("pcie_model_s", Json::num(t_pcie))
                .field("a100_est_bps", Json::num(a100_thpt))
                .field("a100_vs_pcie", Json::num(a100_thpt / pcie_thpt)),
        );
    }
    table.print();
    println!("\n## Parallel two-phase pipeline — thread sweep\n");
    sweep.print();

    // ---- Multi-symbol fast path vs hierarchical fallback ------------
    // Same stream, same output buffer, two resolvers: the flat 16-bit
    // multi-symbol table vs the forced hierarchical byte-walk (the path
    // any codebook outside the fast constraints takes). Decoded bits
    // must be identical — the fast table is an optimization, never a
    // format — and the fast path must be strictly faster (the CI
    // `decode-perf-smoke` job runs this section).
    println!("\n## Sequential decode — multi-symbol fast path vs hierarchical fallback\n");
    let mut fastpath = Table::new(&[
        "elements",
        "fast path",
        "hierarchical",
        "fast speedup",
        "crc32 (both)",
    ]);
    let mut fastpath_rows: Vec<Json> = Vec::new();
    for log2 in [18u32, 20] {
        let n = 1usize << log2;
        let spec = WeightSpec {
            name: format!("lm_head.fastslice{log2}"),
            group: "lm_head".into(),
            shape: [1, n],
            fan_in: 4096,
        };
        let w = generate_weights(&spec, 29);
        let t = Df11Tensor::compress(&w).unwrap();
        let mut out = vec![Bf16::from_bits(0); n];
        let r_fast = bench.bench("fast", || decompress_sequential_into(&t, &mut out).unwrap());
        assert_eq!(out, w, "fast path must stay bit-exact");
        let crc_fast = bits_crc(&out);
        let r_hier = bench.bench("hier", || {
            decompress_sequential_hierarchical_into(&t, &mut out).unwrap()
        });
        assert_eq!(out, w, "hierarchical fallback must stay bit-exact");
        let crc_hier = bits_crc(&out);
        assert_eq!(crc_fast, crc_hier, "fast and hierarchical CRCs diverged");
        assert!(
            r_fast.mean < r_hier.mean,
            "the multi-symbol fast path must beat the hierarchical walk at \
             n=2^{log2} ({:.1}us vs {:.1}us)",
            r_fast.mean * 1e6,
            r_hier.mean * 1e6
        );
        let bf16_bytes = (n * 2) as u64;
        fastpath.row(&[
            format!("2^{log2}"),
            fmt::throughput_bps(bf16_bytes as f64 / r_fast.mean),
            fmt::throughput_bps(bf16_bytes as f64 / r_hier.mean),
            format!("{:.2}x", r_hier.mean / r_fast.mean),
            format!("{crc_fast:#010x}"),
        ]);
        fastpath_rows.push(
            Json::obj()
                .field("log2_elements", Json::int(log2 as u64))
                .field("fast_s", Json::num(r_fast.mean))
                .field("hierarchical_s", Json::num(r_hier.mean))
                .field("fast_speedup", Json::num(r_hier.mean / r_fast.mean))
                .field("crc32", Json::int(crc_fast as u64)),
        );
    }
    fastpath.print();

    // ---- Decoded-block cache (serving) ------------------------------
    // The same workload served cache-off vs cache-on (a capacity that
    // holds the whole model): warm ticks skip Huffman decode entirely
    // and charge a simulated HBM read instead, so the simulated serve
    // clock drops while the token digest stays bit-identical.
    println!("\n## Decoded-block cache — cache-off vs cache-on serving\n");
    let cache_cfg = ModelConfig {
        name: "fig7-cache".into(),
        vocab_size: 256,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 256,
        max_seq_len: 64,
        tie_embeddings: false,
    };
    let workload: Vec<Request> = (0..4)
        .map(|i| Request::new(vec![(i * 7 % 50 + 1) as u32, 2, 3], 6))
        .collect();
    let serve = |cache: BlockCacheMode| {
        let engine = Engine::build(&cache_cfg, 53, WeightMode::Df11).unwrap();
        let mut server = Server::new(
            engine,
            SchedulerConfig {
                max_batch: 2,
                block_cache: cache,
                ..SchedulerConfig::default()
            },
        );
        for r in &workload {
            server.submit(r.clone()).unwrap();
        }
        server.drain().unwrap()
    };
    let off = serve(BlockCacheMode::Off);
    let on = serve(BlockCacheMode::Bytes(1 << 30));
    assert_eq!(
        tokens_crc(&off),
        tokens_crc(&on),
        "block cache changed served tokens"
    );
    let stats = on.block_cache.expect("cache-on run reports stats");
    assert!(stats.hits > 0, "warm cache-on serving must hit");
    let mut cache_table = Table::new(&[
        "mode",
        "hits",
        "misses",
        "evictions",
        "sim serve time",
        "tokens-crc32",
    ]);
    cache_table.row(&[
        "off".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        fmt::seconds(off.total_seconds),
        format!("{:#010x}", tokens_crc(&off)),
    ]);
    cache_table.row(&[
        "on (1 GiB)".into(),
        stats.hits.to_string(),
        stats.misses.to_string(),
        stats.evictions.to_string(),
        fmt::seconds(on.total_seconds),
        format!("{:#010x}", tokens_crc(&on)),
    ]);
    cache_table.print();
    let cache_json = Json::obj()
        .field("hits", Json::int(stats.hits))
        .field("misses", Json::int(stats.misses))
        .field("evictions", Json::int(stats.evictions))
        .field("capacity_bytes", Json::int(stats.capacity))
        .field("cache_off_sim_s", Json::num(off.total_seconds))
        .field("cache_on_sim_s", Json::num(on.total_seconds))
        .field("tokens_crc32", Json::int(tokens_crc(&off) as u64));

    // ---- Persistent pool vs per-call spawn --------------------------
    // The resident-decoder claim: on small blocks, per-call worker
    // spawn/join dominates the decode itself. The persistent-pool arm
    // reuses one warm pool; the per-call arm pays a fresh 8-worker
    // pool spawn + shutdown on every decode, which is what the old
    // `std::thread::scope` pipeline paid implicitly.
    println!("\n## Persistent pool vs per-call spawn (width 8, small blocks)\n");
    let mut resident = Table::new(&[
        "elements",
        "bf16 bytes",
        "persistent pool",
        "per-call spawn",
        "persistent speedup",
    ]);
    let warm = WorkerPool::new(8);
    let mut resident_rows: Vec<Json> = Vec::new();
    for log2 in [13u32, 14, 15] {
        // 8k–32k elements = 16–64 KiB of BF16: all at or under 64 KiB.
        let n = 1usize << log2;
        let spec = WeightSpec {
            name: format!("small.slice{log2}"),
            group: "small".into(),
            shape: [1, n],
            fan_in: 4096,
        };
        let w = generate_weights(&spec, 23);
        let t = Df11Tensor::compress(&w).unwrap();
        let mut out = vec![Bf16::from_bits(0); n];
        let r_pool = bench.bench("pool", || {
            decompress_pooled_into(&t, &mut out, 8, &warm).unwrap();
        });
        assert_eq!(out, w, "pooled decode must stay bit-exact");
        let r_spawn = bench.bench("spawn", || {
            let fresh = WorkerPool::new(8);
            decompress_pooled_into(&t, &mut out, 8, &fresh).unwrap();
        });
        assert_eq!(out, w, "per-call-spawn decode must stay bit-exact");
        let bf16_bytes = (n * 2) as u64;
        resident.row(&[
            format!("2^{log2}"),
            fmt::bytes(bf16_bytes),
            fmt::throughput_bps(bf16_bytes as f64 / r_pool.mean),
            fmt::throughput_bps(bf16_bytes as f64 / r_spawn.mean),
            format!("{:.2}x", r_spawn.mean / r_pool.mean),
        ]);
        resident_rows.push(
            Json::obj()
                .field("log2_elements", Json::int(log2 as u64))
                .field("persistent_pool_s", Json::num(r_pool.mean))
                .field("per_call_spawn_s", Json::num(r_spawn.mean))
                .field("persistent_speedup", Json::num(r_spawn.mean / r_pool.mean)),
        );
        assert!(
            r_pool.mean <= r_spawn.mean,
            "persistent pool must beat per-call spawn on {n}-element blocks \
             ({:.1}us vs {:.1}us)",
            r_pool.mean * 1e6,
            r_spawn.mean * 1e6
        );
    }
    resident.print();

    println!(
        "\npaper: DF11 up to 34.95x faster than CPU->GPU transfer and up to \
         20.97x faster than nvCOMP ANS; throughput rises with matrix size.\n\
         NOTE: our measured columns are CPU wall-clock (simulation substrate); \
         the orderings and the size scaling are the reproduced claims — the \
         A100 column gives the calibrated device estimate (~200 GB/s peak). \
         The thread sweep reproduces the two-phase kernel's parallel scaling \
         on CPU cores; speedups saturate at the host's physical core count. \
         The persistent-pool table is the CPU analogue of keeping the decode \
         kernel resident: per-call worker spawn/join is the Huff-LLM-style \
         overhead the pool amortizes away."
    );

    let artifact = Json::obj()
        .field("bench", Json::str("fig7"))
        .field("provenance", Json::str("measured"))
        .field("decompress_vs_size", Json::Array(size_rows))
        .field("thread_sweep", Json::Array(sweep_rows))
        .field("decode_fast_path", Json::Array(fastpath_rows))
        .field("block_cache", cache_json)
        .field("persistent_pool", Json::Array(resident_rows));
    match write_artifact("fig7", &artifact) {
        Ok(Some(path)) => println!("wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("artifact write failed: {e}"),
    }
}
