"""AOT lowering: JAX functions -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (shapes fixed at lowering time, recorded in meta.json):

  block_fwd_b{B}.hlo.txt   one decoder block, decode step, batch B
  embed_b{B}.hlo.txt       token embedding gather, batch B
  lm_head_b{B}.hlo.txt     final norm + LM head, batch B
  df11_decode.hlo.txt      the L1 Pallas DF11 decode kernel (demo shape)

Run once via `make artifacts`; the Rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Must match rust/src/model/mod.rs::ModelConfig::tiny_100m().
TINY_100M = dict(
    name="tiny-llama-100m",
    vocab_size=256,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2304,
    max_seq_len=512,
)

BATCH_SIZES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_block_fwd(cfg: dict, batch: int) -> str:
    d = cfg["d_model"]
    kv = cfg["n_kv_heads"] * (d // cfg["n_heads"])
    ff = cfg["d_ff"]
    ms = cfg["max_seq_len"]

    def fn(x, q, k, v, o, gate, up, down, kc, vc, pos):
        xo, kco, vco = model.block_forward(
            x, q, k, v, o, gate, up, down, kc, vc, pos,
            cfg["n_heads"], cfg["n_kv_heads"],
        )
        return (xo, kco, vco)

    lowered = jax.jit(fn).lower(
        spec((batch, d)),
        spec((d, d)),
        spec((d, kv)),
        spec((d, kv)),
        spec((d, d)),
        spec((d, ff)),
        spec((d, ff)),
        spec((ff, d)),
        spec((batch, ms, kv)),
        spec((batch, ms, kv)),
        spec((), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_embed(cfg: dict, batch: int) -> str:
    def fn(tokens, emb):
        return (model.embed(tokens, emb),)

    lowered = jax.jit(fn).lower(
        spec((batch,), jnp.int32),
        spec((cfg["vocab_size"], cfg["d_model"])),
    )
    return to_hlo_text(lowered)


def lower_lm_head(cfg: dict, batch: int) -> str:
    def fn(x, w):
        return (model.lm_head(x, w),)

    lowered = jax.jit(fn).lower(
        spec((batch, cfg["d_model"])),
        spec((cfg["d_model"], cfg["vocab_size"])),
    )
    return to_hlo_text(lowered)


def lower_df11_decode() -> tuple[str, dict]:
    """Lower the L1 Pallas decode kernel at a fixed demo shape.

    The encoded stream for the demo shape is produced by ref.encode at
    runtime-prep time; what we fix here are the array *sizes*, recorded
    in meta.json so the Rust quickstart can build matching inputs.
    """
    from .kernels import ref
    from .kernels.dfloat11 import _decode_kernel
    from jax.experimental import pallas as pl

    # Deterministic demo tensor (seed fixed; ~8k weights).
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(8192) * 0.02).astype(np.float32)
    bits = (x.view(np.uint32) >> 16).astype(np.uint16)
    enc = ref.encode(bits)

    chunks_per_program = 8
    num_chunks = len(enc.gaps)
    grid = (num_chunks + chunks_per_program - 1) // chunks_per_program
    kernel = partial(
        _decode_kernel,
        bytes_per_chunk=enc.bytes_per_chunk,
        bit_len=enc.bit_len,
        chunks_per_program=chunks_per_program,
        num_chunks=num_chunks,
    )

    def fn(encoded, gaps, outpos, luts, lens, sm):
        out = pl.pallas_call(
            kernel,
            grid=(grid,),
            out_shape=jax.ShapeDtypeStruct((enc.num_elements,), jnp.uint16),
            interpret=True,
        )(encoded, gaps, outpos, luts, lens, sm)
        return (out,)

    lowered = jax.jit(fn).lower(
        spec((len(enc.encoded),), jnp.uint8),
        spec((num_chunks,), jnp.int32),
        spec((num_chunks,), jnp.int32),
        spec(enc.luts.shape, jnp.int32),
        spec((256,), jnp.int32),
        spec((enc.num_elements,), jnp.uint8),
    )
    meta = dict(
        num_elements=enc.num_elements,
        num_chunks=num_chunks,
        encoded_len=len(enc.encoded),
        num_luts=int(enc.luts.shape[0]),
        bit_len=enc.bit_len,
        bytes_per_chunk=enc.bytes_per_chunk,
        seed=11,
    )
    return to_hlo_text(lowered), meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-pallas",
        action="store_true",
        help="skip the (slow to trace) pallas demo artifact",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = TINY_100M
    meta = {"model": cfg, "batch_sizes": list(BATCH_SIZES), "artifacts": {}}

    for b in BATCH_SIZES:
        for name, text in [
            (f"block_fwd_b{b}", lower_block_fwd(cfg, b)),
            (f"embed_b{b}", lower_embed(cfg, b)),
            (f"lm_head_b{b}", lower_lm_head(cfg, b)),
        ]:
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            meta["artifacts"][name] = f"{name}.hlo.txt"
            print(f"wrote {path} ({len(text)} chars)")

    if not args.skip_pallas:
        text, df11_meta = lower_df11_decode()
        path = os.path.join(args.out_dir, "df11_decode.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"]["df11_decode"] = "df11_decode.hlo.txt"
        meta["df11_decode"] = df11_meta
        print(f"wrote {path} ({len(text)} chars)")
        # Dump the demo container as flat little-endian binaries so the
        # Rust quickstart can execute the artifact on REAL data and
        # verify bit-exactness without a Python runtime dependency.
        from .kernels import ref as _ref

        rng = np.random.default_rng(df11_meta["seed"])
        x = (rng.standard_normal(df11_meta["num_elements"]) * 0.02).astype(np.float32)
        bits = (x.view(np.uint32) >> 16).astype(np.uint16)
        enc = _ref.encode(bits)
        demo = {
            "demo_encoded.bin": enc.encoded.astype(np.uint8),
            "demo_gaps.bin": enc.gaps.astype("<i4"),
            "demo_outpos.bin": enc.chunk_out_pos.astype("<i4"),
            "demo_luts.bin": enc.luts.astype("<i4"),
            "demo_lens.bin": enc.code_lengths.astype("<i4"),
            "demo_sm.bin": enc.sign_mantissa.astype(np.uint8),
            "demo_expected.bin": bits.astype("<u2"),
        }
        for name, arr in demo.items():
            arr.tofile(os.path.join(args.out_dir, name))
        print("wrote demo container binaries")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("wrote meta.json")


if __name__ == "__main__":
    main()
