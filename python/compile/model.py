"""L2: the Llama-style transformer in JAX (build-time only).

These functions define the compute graph the Rust coordinator executes
through PJRT: `aot.py` lowers them to HLO text with fixed shapes, and
`rust/src/runtime/` loads + compiles + runs the artifacts on the request
path (Python never runs at serving time).

The math mirrors `rust/src/nn/` + `coordinator::engine::NativeBackend`
one-to-one (RMSNorm eps 1e-6 with unit gain, RoPE theta 10000, SiLU
gated MLP, GQA attention over a fixed-size KV cache with positions
masked beyond `pos`), so the native and PJRT backends are numerically
interchangeable.

The DF11 story at this layer: decompressed BF16 weights arrive as
*arguments* (decompression happens in the Rust coordinator or in the L1
Pallas kernel); the block forward feeds them straight into `jnp.dot` —
on a real TPU these hit the MXU in bf16, here f32 keeps CPU-PJRT
numerics exact vs the Rust reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6
ROPE_THETA = 1e4


def rmsnorm(x: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm with unit gain over the last axis."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + EPS)


def rope(x: jnp.ndarray, n_heads: int, head_dim: int, pos) -> jnp.ndarray:
    """Rotary embedding for a single position.

    `x` is (batch, n_heads * head_dim); `pos` is a scalar (traced).
    """
    b = x.shape[0]
    xs = x.reshape(b, n_heads, head_dim)
    half = head_dim // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = 1.0 / (ROPE_THETA ** (2.0 * i / head_dim))
    angle = pos.astype(jnp.float32) * freq
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    a = xs[..., :half]
    bb = xs[..., half:]
    rot = jnp.concatenate([a * cos - bb * sin, a * sin + bb * cos], axis=-1)
    return rot.reshape(b, n_heads * head_dim)


def embed(tokens: jnp.ndarray, embed_matrix: jnp.ndarray) -> jnp.ndarray:
    """Token embedding gather: (batch,) x (vocab, d) -> (batch, d)."""
    return jnp.take(embed_matrix, tokens, axis=0)


def block_forward(
    x: jnp.ndarray,  # (batch, d)
    q_w: jnp.ndarray,  # (d, d)
    k_w: jnp.ndarray,  # (d, kv)
    v_w: jnp.ndarray,  # (d, kv)
    o_w: jnp.ndarray,  # (d, d)
    gate_w: jnp.ndarray,  # (d, ff)
    up_w: jnp.ndarray,  # (d, ff)
    down_w: jnp.ndarray,  # (ff, d)
    k_cache: jnp.ndarray,  # (batch, max_seq, kv)
    v_cache: jnp.ndarray,  # (batch, max_seq, kv)
    pos: jnp.ndarray,  # scalar int32
    n_heads: int,
    n_kv_heads: int,
):
    """One decoder block, single-token decode step.

    Returns (x_out, k_cache_out, v_cache_out).
    """
    b, d = x.shape
    kv = k_w.shape[1]
    head_dim = d // n_heads
    group = n_heads // n_kv_heads
    max_seq = k_cache.shape[1]

    h = rmsnorm(x)
    q = h @ q_w
    k = h @ k_w
    v = h @ v_w
    q = rope(q, n_heads, head_dim, pos)
    k = rope(k, n_kv_heads, head_dim, pos)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k[:, None, :], (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v[:, None, :], (0, pos, 0))

    # GQA attention over positions [0, pos].
    qh = q.reshape(b, n_heads, head_dim)
    kh = k_cache.reshape(b, max_seq, n_kv_heads, head_dim)
    vh = v_cache.reshape(b, max_seq, n_kv_heads, head_dim)
    # Expand kv heads to query heads.
    kh = jnp.repeat(kh, group, axis=2)  # (b, max_seq, n_heads, head_dim)
    vh = jnp.repeat(vh, group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", qh, kh) / jnp.sqrt(
        jnp.asarray(head_dim, dtype=x.dtype)
    )
    mask = jnp.arange(max_seq)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhs,bshd->bhd", probs, vh).reshape(b, d)
    x = x + attn @ o_w

    h2 = rmsnorm(x)
    g = h2 @ gate_w
    u = h2 @ up_w
    x = x + (jax.nn.silu(g) * u) @ down_w
    return x, k_cache, v_cache


def lm_head(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Final norm + LM head: (batch, d) x (d, vocab) -> (batch, vocab)."""
    return rmsnorm(x) @ w


def decode_step(params: dict, tokens: jnp.ndarray, k_caches, v_caches, pos):
    """A full fused decode step (used by the e2e artifact): embed ->
    all blocks -> lm head. `params` is a dict of weight arrays; caches
    are lists of per-layer arrays.

    Returns (logits, new_k_caches, new_v_caches).
    """
    n_layers = len(k_caches)
    x = embed(tokens, params["embed.tok"])
    new_k, new_v = [], []
    for l in range(n_layers):
        g = f"block.{l}"
        x, kc, vc = block_forward(
            x,
            params[f"{g}.q_proj"],
            params[f"{g}.k_proj"],
            params[f"{g}.v_proj"],
            params[f"{g}.o_proj"],
            params[f"{g}.gate_proj"],
            params[f"{g}.up_proj"],
            params[f"{g}.down_proj"],
            k_caches[l],
            v_caches[l],
            pos,
            params["n_heads"],
            params["n_kv_heads"],
        )
        new_k.append(kc)
        new_v.append(vc)
    logits = lm_head(x, params["lm_head"])
    return logits, new_k, new_v
