"""L1: the DF11 decompression kernel in Pallas (TPU adaptation).

The paper's CUDA kernel (Algorithm 1) is reorganized for the TPU
execution model — see DESIGN.md § Hardware-Adaptation:

* CUDA **threadblock** -> Pallas **grid program**: each grid step decodes
  one run of `chunks_per_program` chunks of the encoded stream.
* Per-thread **gap array** & per-block **output positions** -> per-chunk
  `gaps` / `chunk_out_pos` auxiliary arrays, precomputed by the encoder.
  With output positions known per chunk, the GPU kernel's phase 1
  (count) + intra-block Blelloch scan collapse into a host-side prefix
  sum, and the device kernel decodes in a **single pass** — TPUs have no
  warp divergence to coordinate around, and the VPU wants one regular
  loop.
* Hierarchical **LUTs in SRAM** -> LUT tables as kernel operands that
  the compiler keeps in VMEM ((k+1) x 256 x 4 bytes, far under the
  ~16 MB budget).
* The decoded BF16 tile feeds `jnp.dot` on the MXU in model.py — the
  paper's decompress-then-GEMM fusion.

`interpret=True` everywhere: the image's PJRT plugin is CPU-only; real
TPU lowering would emit a Mosaic custom-call it cannot execute. The
kernel is structured for TPU but *validated* through the interpreter
against `ref.decode_reference`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from .ref import Df11Encoded, INVALID, POINTER_FLAG


def _decode_kernel(
    encoded_ref,  # uint8[padded_bytes + 4]
    gaps_ref,  # int32[C]
    outpos_ref,  # int32[C]
    luts_ref,  # int32[k, 256]
    lens_ref,  # int32[256]
    sm_ref,  # uint8[N]
    out_ref,  # uint16[N]
    *,
    bytes_per_chunk: int,
    bit_len: int,
    chunks_per_program: int,
    num_chunks: int,
):
    """One grid program: decode `chunks_per_program` consecutive chunks."""
    pid = pl.program_id(0)
    chunk_bits = bytes_per_chunk * 8

    def read_byte_window(bitpos):
        """The next 8 bits starting at `bitpos`, as an int32 in [0, 255]."""
        byte_idx = bitpos // 8
        off = bitpos % 8
        b0 = pl.load(encoded_ref, (byte_idx,)).astype(jnp.int32)
        b1 = pl.load(encoded_ref, (byte_idx + 1,)).astype(jnp.int32)
        # off == 0 would make `b1 >> 8` shift by the full width; guard it.
        shifted = ((b0 << off) | (b1 >> jnp.maximum(8 - off, 0))) & 0xFF
        return jnp.where(off == 0, b0, shifted)

    def decode_one(bitpos):
        """Walk the LUT hierarchy: returns (symbol, code_len)."""

        def cond(state):
            _, entry, _ = state
            return entry >= POINTER_FLAG

        def body(state):
            level, entry, _ = state
            table = entry - POINTER_FLAG
            byte = read_byte_window(bitpos + level * 8)
            nxt = pl.load(luts_ref, (table, byte))
            return level + 1, nxt, byte

        byte0 = read_byte_window(bitpos)
        entry0 = pl.load(luts_ref, (0, byte0))
        # Start as if table 0 were pointed to; loop chases pointers.
        _, entry, _ = lax.while_loop(cond, body, (jnp.int32(1), entry0, byte0))
        symbol = entry
        length = pl.load(lens_ref, (symbol,))
        return symbol, length

    def do_chunk(i, _):
        c = pid * chunks_per_program + i
        in_range = c < num_chunks

        def run(_):
            chunk_start = c * chunk_bits
            chunk_end = jnp.minimum(chunk_start + chunk_bits, bit_len)
            start = chunk_start + pl.load(gaps_ref, (c,))
            out0 = pl.load(outpos_ref, (c,))

            def cond(state):
                bitpos, _ = state
                return bitpos < chunk_end

            def body(state):
                bitpos, idx = state
                symbol, length = decode_one(bitpos)
                sm = pl.load(sm_ref, (idx,)).astype(jnp.int32)
                word = ((sm >> 7) << 15) | (symbol << 7) | (sm & 0x7F)
                pl.store(out_ref, (idx,), word.astype(jnp.uint16))
                return bitpos + length, idx + 1

            lax.while_loop(cond, body, (start, out0))
            return 0

        lax.cond(in_range, run, lambda _: 0, 0)
        return ()

    lax.fori_loop(0, chunks_per_program, do_chunk, ())


def decode_pallas(enc: Df11Encoded, chunks_per_program: int = 8) -> np.ndarray:
    """Decode a DF11-encoded tensor with the Pallas kernel.

    Returns uint16 BF16 bit patterns, bit-for-bit equal to the input of
    `ref.encode`.
    """
    num_chunks = len(enc.gaps)
    grid = (num_chunks + chunks_per_program - 1) // chunks_per_program
    if enc.luts.min() < INVALID:
        raise ValueError("bad LUT entries")

    kernel = functools.partial(
        _decode_kernel,
        bytes_per_chunk=enc.bytes_per_chunk,
        bit_len=enc.bit_len,
        chunks_per_program=chunks_per_program,
        num_chunks=num_chunks,
    )
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        out_shape=jax.ShapeDtypeStruct((enc.num_elements,), jnp.uint16),
        interpret=True,
    )(
        jnp.asarray(enc.encoded, dtype=jnp.uint8),
        jnp.asarray(enc.gaps, dtype=jnp.int32),
        jnp.asarray(enc.chunk_out_pos, dtype=jnp.int32),
        jnp.asarray(enc.luts, dtype=jnp.int32),
        jnp.asarray(enc.code_lengths, dtype=jnp.int32),
        jnp.asarray(enc.sign_mantissa, dtype=jnp.uint8),
    )
    return np.asarray(out)


def decode_to_bf16(enc: Df11Encoded, shape: tuple[int, ...], chunks_per_program: int = 8):
    """Decode and bitcast to a bfloat16 jax array of `shape` (the form
    the L2 model consumes before feeding the MXU)."""
    bits = decode_pallas(enc, chunks_per_program)
    return lax.bitcast_convert_type(
        jnp.asarray(bits).reshape(shape), jnp.bfloat16
    )


def vmem_footprint_bytes(enc: Df11Encoded, chunks_per_program: int = 8) -> int:
    """Estimated VMEM residency per grid step (DESIGN.md §6: LUTs +
    CodeLengths + the working chunk window + aux slices).

    This is the quantity we report against the ~16 MB VMEM budget in
    lieu of real-TPU profiling (interpret mode gives no hardware
    counters).
    """
    luts = enc.luts.size * 4 + 256 * 4
    window = chunks_per_program * enc.bytes_per_chunk + 4
    aux = chunks_per_program * 8  # gap + outpos slices
    return luts + window + aux
