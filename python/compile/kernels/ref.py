"""Pure-Python/NumPy DF11 reference: encoder + oracle decoder.

This is the build-time half of the L1 kernel story:

* the **encoder** mirrors the Rust container format (canonical Huffman
  over BF16 exponents, MSB-first bit packing, per-chunk gap array and
  output positions) so the Pallas kernel can be tested on realistic
  inputs without the Rust toolchain;
* the **oracle decoder** (`decode_reference`) is the trivially-correct
  sequential implementation the Pallas kernel is validated against in
  pytest (python/tests/test_kernel.py).

Build-time only: nothing here runs on the serving path.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

MAX_CODE_LEN = 32
# Wide LUT entry encoding: values < 256 decode a symbol; >= POINTER_FLAG
# point at table (entry - POINTER_FLAG); INVALID marks impossible prefixes.
POINTER_FLAG = 256
INVALID = -1


def split_planes(bits: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split uint16 BF16 bit patterns into (exponent, sign_mantissa) bytes."""
    bits = bits.astype(np.uint32)
    exponents = ((bits >> 7) & 0xFF).astype(np.uint8)
    sign_mantissa = (((bits >> 8) & 0x80) | (bits & 0x7F)).astype(np.uint8)
    return exponents, sign_mantissa


def merge_planes(exponents: np.ndarray, sign_mantissa: np.ndarray) -> np.ndarray:
    """Reassemble uint16 BF16 bits from the two planes."""
    e = exponents.astype(np.uint32)
    sm = sign_mantissa.astype(np.uint32)
    return (((sm >> 7) << 15) | (e << 7) | (sm & 0x7F)).astype(np.uint16)


def huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Optimal Huffman code lengths for 256 byte symbols (0 = unused)."""
    symbols = [s for s in range(256) if freqs[s] > 0]
    lengths = np.zeros(256, dtype=np.uint8)
    if not symbols:
        raise ValueError("no symbols")
    if len(symbols) == 1:
        lengths[symbols[0]] = 1
        return lengths
    # Heap of (freq, tiebreak_id, node). Leaves are indices into symbols.
    parent: dict[int, int] = {}
    heap = [(int(freqs[s]), i, i) for i, s in enumerate(symbols)]
    heapq.heapify(heap)
    next_id = len(symbols)
    while len(heap) > 1:
        fa, _, a = heapq.heappop(heap)
        fb, _, b = heapq.heappop(heap)
        parent[a] = next_id
        parent[b] = next_id
        heapq.heappush(heap, (fa + fb, next_id, next_id))
        next_id += 1
    for i, s in enumerate(symbols):
        depth = 0
        cur = i
        while cur in parent:
            cur = parent[cur]
            depth += 1
        if depth > MAX_CODE_LEN:
            raise ValueError(f"code length {depth} exceeds {MAX_CODE_LEN}")
        lengths[s] = depth
    return lengths


def canonical_codes(lengths: np.ndarray) -> dict[int, tuple[int, int]]:
    """Canonical code assignment: symbol -> (bits, len)."""
    order = sorted(
        (s for s in range(256) if lengths[s] > 0), key=lambda s: (lengths[s], s)
    )
    codes: dict[int, tuple[int, int]] = {}
    code = 0
    prev = 0
    for s in order:
        ln = int(lengths[s])
        if prev:
            code = (code + 1) << (ln - prev)
        prev = ln
        codes[s] = (code, ln)
    return codes


def build_wide_luts(lengths: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Hierarchical 256-entry LUTs in the kernel-friendly wide layout.

    Returns (luts[int32, k x 256], code_lengths[int32, 256]).
    """
    codes = canonical_codes(lengths)
    tables = [np.full(256, INVALID, dtype=np.int32)]
    path_index: dict[tuple[int, ...], int] = {(): 0}

    def table_for(path: tuple[int, ...]) -> int:
        if path in path_index:
            return path_index[path]
        parent_t = table_for(path[:-1])
        idx = len(tables)
        tables.append(np.full(256, INVALID, dtype=np.int32))
        path_index[path] = idx
        assert tables[parent_t][path[-1]] == INVALID, "pointer collision"
        tables[parent_t][path[-1]] = POINTER_FLAG + idx
        return idx

    for s, (bits, ln) in codes.items():
        depth = (ln - 1) // 8
        fill = (depth + 1) * 8 - ln
        aligned = bits << fill
        path = tuple((aligned >> ((depth - d) * 8)) & 0xFF for d in range(depth))
        t = table_for(path)
        last = aligned & 0xFF
        for e in range(last, last + (1 << fill)):
            assert tables[t][e] == INVALID, "entry collision"
            tables[t][e] = s
    code_lengths = lengths.astype(np.int32)
    return np.stack(tables), code_lengths


@dataclass
class Df11Encoded:
    """A DF11-encoded tensor (python mirror of the Rust container)."""

    encoded: np.ndarray  # uint8, padded to whole chunks (+4 spill bytes)
    bit_len: int
    gaps: np.ndarray  # int32 per chunk
    chunk_out_pos: np.ndarray  # int32 per chunk (TPU adaptation: per chunk)
    luts: np.ndarray  # int32 (k, 256)
    code_lengths: np.ndarray  # int32 (256,)
    sign_mantissa: np.ndarray  # uint8 (n,)
    num_elements: int
    bytes_per_chunk: int


def encode(bits_u16: np.ndarray, bytes_per_chunk: int = 8) -> Df11Encoded:
    """Encode BF16 bit patterns into the DF11 layout.

    The gap array and per-chunk output positions are computed exactly as
    the Rust encoder does (including the gap=31 sentinel for a trailing
    chunk that contains only the tail of the final codeword).
    """
    bits_u16 = np.asarray(bits_u16, dtype=np.uint16).ravel()
    exponents, sign_mantissa = split_planes(bits_u16)
    freqs = np.bincount(exponents, minlength=256).astype(np.uint64)
    lengths = huffman_code_lengths(freqs)
    codes = canonical_codes(lengths)
    luts, code_lengths = build_wide_luts(lengths)

    len_arr = np.zeros(256, dtype=np.uint64)
    for s, (_, ln) in codes.items():
        len_arr[s] = ln
    sym_lens = len_arr[exponents]
    bit_len = int(sym_lens.sum())

    chunk_bits = bytes_per_chunk * 8
    num_chunks = max((bit_len + chunk_bits - 1) // chunk_bits, 1)

    # Code start offsets (exclusive prefix sum of lengths).
    starts = np.zeros(len(exponents), dtype=np.uint64)
    if len(exponents) > 1:
        starts[1:] = np.cumsum(sym_lens[:-1])

    # Bit-pack MSB-first.
    out = bytearray(num_chunks * bytes_per_chunk + 4)  # +4 spill window
    acc = 0
    acc_bits = 0
    pos = 0
    for s in exponents:
        b, ln = codes[int(s)]
        acc = (acc << ln) | b
        acc_bits += ln
        while acc_bits >= 8:
            acc_bits -= 8
            out[pos] = (acc >> acc_bits) & 0xFF
            pos += 1
        acc &= (1 << acc_bits) - 1
    if acc_bits:
        out[pos] = (acc << (8 - acc_bits)) & 0xFF

    # Gap array + per-chunk counts. Chunks without a code start keep the
    # gap=31 sentinel (provably lands at/after bit_len -> kernel skips).
    gaps = np.full(num_chunks, 31, dtype=np.int32)
    counts = np.zeros(num_chunks, dtype=np.int64)
    chunk_of = (starts // chunk_bits).astype(np.int64)
    np.add.at(counts, chunk_of, 1)
    first_idx = np.full(num_chunks, -1, dtype=np.int64)
    for i in range(len(exponents) - 1, -1, -1):
        first_idx[chunk_of[i]] = i
    has = first_idx >= 0
    gaps[has] = (
        starts[first_idx[has]] - chunk_of[first_idx[has]].astype(np.uint64) * chunk_bits
    ).astype(np.int32)

    chunk_out_pos = np.zeros(num_chunks, dtype=np.int32)
    if num_chunks > 1:
        chunk_out_pos[1:] = np.cumsum(counts[:-1]).astype(np.int32)

    return Df11Encoded(
        encoded=np.frombuffer(bytes(out), dtype=np.uint8),
        bit_len=bit_len,
        gaps=gaps,
        chunk_out_pos=chunk_out_pos,
        luts=luts,
        code_lengths=code_lengths,
        sign_mantissa=sign_mantissa,
        num_elements=len(exponents),
        bytes_per_chunk=bytes_per_chunk,
    )


def decode_reference(enc: Df11Encoded) -> np.ndarray:
    """Sequential oracle decoder: returns uint16 BF16 bit patterns."""
    out = np.zeros(enc.num_elements, dtype=np.uint16)
    data = enc.encoded
    bitpos = 0
    for i in range(enc.num_elements):
        table = 0
        level = 0
        while True:
            byte_idx = (bitpos + level * 8) // 8
            off = (bitpos + level * 8) % 8
            b0 = int(data[byte_idx])
            b1 = int(data[byte_idx + 1]) if byte_idx + 1 < len(data) else 0
            window = ((b0 << off) | (b1 >> (8 - off))) & 0xFF if off else b0
            entry = int(enc.luts[table][window])
            if entry == INVALID:
                raise ValueError(f"invalid prefix at bit {bitpos}")
            if entry >= POINTER_FLAG:
                table = entry - POINTER_FLAG
                level += 1
                continue
            symbol = entry
            break
        ln = int(enc.code_lengths[symbol])
        sm = int(enc.sign_mantissa[i])
        out[i] = ((sm >> 7) << 15) | (symbol << 7) | (sm & 0x7F)
        bitpos += ln
    if bitpos != enc.bit_len:
        raise ValueError(f"consumed {bitpos} bits, expected {enc.bit_len}")
    return out


def compression_ratio(enc: Df11Encoded) -> float:
    """Compressed bytes / original bytes (Table 1's ratio, python side)."""
    comp = (
        len(enc.encoded)
        + enc.sign_mantissa.nbytes
        + (len(enc.gaps) * 5 + 7) // 8
        + enc.chunk_out_pos.nbytes
        + 256
    )
    return comp / (enc.num_elements * 2)
