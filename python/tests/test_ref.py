"""Tests for the python-side DF11 reference encoder/decoder."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def gaussian_bits(n: int, seed: int, std: float = 0.02) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * std).astype(np.float32)
    return (x.view(np.uint32) >> 16).astype(np.uint16)


class TestPlanes:
    def test_split_merge_roundtrip_all_patterns(self):
        bits = np.arange(65536, dtype=np.uint16)
        e, sm = ref.split_planes(bits)
        assert np.array_equal(ref.merge_planes(e, sm), bits)

    def test_known_pattern(self):
        # 1.0bf16 = 0x3F80: sign 0, exponent 127, mantissa 0.
        e, sm = ref.split_planes(np.array([0x3F80], dtype=np.uint16))
        assert e[0] == 127
        assert sm[0] == 0
        # -1.5 = 0xBFC0: sign 1, exponent 127, mantissa 0x40.
        e, sm = ref.split_planes(np.array([0xBFC0], dtype=np.uint16))
        assert e[0] == 127
        assert sm[0] == 0x80 | 0x40


class TestHuffman:
    def test_kraft_equality(self):
        freqs = np.zeros(256, dtype=np.uint64)
        for i, f in enumerate([45, 13, 12, 16, 9, 5]):
            freqs[i] = f
        lengths = ref.huffman_code_lengths(freqs)
        kraft = sum(2.0 ** -int(l) for l in lengths if l > 0)
        assert abs(kraft - 1.0) < 1e-12

    def test_single_symbol(self):
        freqs = np.zeros(256, dtype=np.uint64)
        freqs[42] = 10
        lengths = ref.huffman_code_lengths(freqs)
        assert lengths[42] == 1
        assert lengths.sum() == 1

    def test_empty_rejected(self):
        with pytest.raises(ValueError):
            ref.huffman_code_lengths(np.zeros(256, dtype=np.uint64))

    def test_canonical_codes_prefix_free(self):
        freqs = np.zeros(256, dtype=np.uint64)
        rng = np.random.default_rng(3)
        for s in rng.choice(256, size=40, replace=False):
            freqs[s] = int(rng.integers(1, 10_000))
        lengths = ref.huffman_code_lengths(freqs)
        codes = ref.canonical_codes(lengths)
        items = list(codes.values())
        for i, (b1, l1) in enumerate(items):
            for b2, l2 in items[i + 1:]:
                (sb, sl), (lb, ll) = ((b1, l1), (b2, l2)) if l1 <= l2 else ((b2, l2), (b1, l1))
                assert (lb >> (ll - sl)) != sb, "prefix violation"


class TestEncodeDecode:
    def test_roundtrip_gaussian(self):
        bits = gaussian_bits(10_000, 0)
        enc = ref.encode(bits)
        assert np.array_equal(ref.decode_reference(enc), bits)

    def test_ratio_near_paper(self):
        bits = gaussian_bits(200_000, 1)
        enc = ref.encode(bits)
        ratio = ref.compression_ratio(enc)
        assert 0.60 < ratio < 0.80, ratio

    def test_gaps_are_five_bit(self):
        bits = gaussian_bits(20_000, 2)
        enc = ref.encode(bits)
        assert enc.gaps.max() < 32
        assert enc.gaps.min() >= 0

    def test_outpos_monotone_and_total(self):
        bits = gaussian_bits(5_000, 3)
        enc = ref.encode(bits)
        assert np.all(np.diff(enc.chunk_out_pos) >= 0)

    def test_special_values(self):
        bits = gaussian_bits(1000, 4)
        bits[0] = 0x7FC0  # NaN
        bits[1] = 0x7F80  # +Inf
        bits[2] = 0xFF80  # -Inf
        bits[3] = 0x0000  # 0
        bits[4] = 0x8000  # -0
        bits[5] = 0x0001  # subnormal
        enc = ref.encode(bits)
        assert np.array_equal(ref.decode_reference(enc), bits)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=3000),
        seed=st.integers(min_value=0, max_value=2**31),
        chunk=st.sampled_from([2, 4, 8, 16]),
    )
    def test_roundtrip_hypothesis(self, n, seed, chunk):
        rng = np.random.default_rng(seed)
        bits = rng.integers(0, 65536, size=n, dtype=np.uint16)
        enc = ref.encode(bits, bytes_per_chunk=chunk)
        assert np.array_equal(ref.decode_reference(enc), bits)

    def test_luts_stay_compact(self):
        # Paper §2.3.1: k in 4..8 tables for LLM exponent distributions.
        bits = gaussian_bits(500_000, 5)
        enc = ref.encode(bits)
        assert enc.luts.shape[0] <= 8
        sram = enc.luts.shape[0] * 256 + 256  # paper's u8 layout equivalent
        assert sram < 100 * 1024
