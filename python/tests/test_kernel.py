"""The CORE correctness signal: Pallas kernel vs the pure oracle.

Hypothesis sweeps shapes / distributions / chunk geometries; every case
must decode bit-for-bit.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dfloat11 import decode_pallas, decode_to_bf16, vmem_footprint_bytes


def gaussian_bits(n: int, seed: int, std: float = 0.02) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * std).astype(np.float32)
    return (x.view(np.uint32) >> 16).astype(np.uint16)


class TestPallasKernel:
    def test_matches_reference_basic(self):
        bits = gaussian_bits(4096, 0)
        enc = ref.encode(bits)
        assert np.array_equal(decode_pallas(enc), ref.decode_reference(enc))
        assert np.array_equal(decode_pallas(enc), bits)

    def test_single_element(self):
        bits = gaussian_bits(1, 1)
        enc = ref.encode(bits)
        assert np.array_equal(decode_pallas(enc), bits)

    def test_chunk_boundary_sizes(self):
        # Sizes chosen to land stream ends on / near chunk boundaries.
        for n in [63, 64, 65, 127, 128, 129, 1023, 1024, 1025]:
            bits = gaussian_bits(n, n)
            enc = ref.encode(bits)
            assert np.array_equal(decode_pallas(enc), bits), f"n={n}"

    def test_special_values(self):
        bits = gaussian_bits(2000, 2)
        bits[:6] = [0x7FC0, 0x7F80, 0xFF80, 0x0000, 0x8000, 0x0001]
        enc = ref.encode(bits)
        assert np.array_equal(decode_pallas(enc), bits)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=2000),
        seed=st.integers(min_value=0, max_value=2**31),
        std=st.sampled_from([0.005, 0.02, 0.2, 2.0]),
        chunk=st.sampled_from([4, 8, 16]),
        cpp=st.sampled_from([1, 4, 8]),
    )
    def test_hypothesis_sweep(self, n, seed, std, chunk, cpp):
        bits = gaussian_bits(n, seed, std)
        enc = ref.encode(bits, bytes_per_chunk=chunk)
        out = decode_pallas(enc, chunks_per_program=cpp)
        assert np.array_equal(out, bits)

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=1500),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_arbitrary_bits(self, n, seed):
        # Uniform random u16 — worst-case exponent alphabet (all 256
        # values, near-8-bit entropy). The kernel must stay correct even
        # where compression gains vanish.
        rng = np.random.default_rng(seed)
        bits = rng.integers(0, 65536, size=n, dtype=np.uint16)
        enc = ref.encode(bits)
        assert np.array_equal(decode_pallas(enc), bits)

    def test_decode_to_bf16_shape_and_values(self):
        bits = gaussian_bits(256, 3)
        enc = ref.encode(bits)
        arr = decode_to_bf16(enc, (16, 16))
        assert arr.shape == (16, 16)
        assert str(arr.dtype) == "bfloat16"
        # Bitcast back and compare.
        import jax
        back = np.asarray(
            jax.lax.bitcast_convert_type(arr, jax.numpy.uint16)
        ).ravel()
        assert np.array_equal(back, bits)

    def test_vmem_footprint_under_budget(self):
        # DESIGN.md §6: the kernel's VMEM residency must be far below the
        # ~16 MB TPU budget.
        bits = gaussian_bits(100_000, 4)
        enc = ref.encode(bits)
        vmem = vmem_footprint_bytes(enc)
        assert vmem < 1 * 1024 * 1024, vmem
