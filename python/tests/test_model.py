"""L2 model tests: shapes, cache semantics, and numerical sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = dict(d=32, heads=4, kv_heads=2, ff=64, vocab=64, max_seq=16)


def make_weights(seed=0):
    rng = np.random.default_rng(seed)
    d, ff, vocab = CFG["d"], CFG["ff"], CFG["vocab"]
    kv = CFG["kv_heads"] * (d // CFG["heads"])
    def w(*shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.1)
    return dict(
        q=w(d, d), k=w(d, kv), v=w(d, kv), o=w(d, d),
        gate=w(d, ff), up=w(d, ff), down=w(ff, d),
        emb=w(vocab, d), head=w(d, vocab),
    )


def run_block(x, ws, kc, vc, pos):
    return model.block_forward(
        x, ws["q"], ws["k"], ws["v"], ws["o"], ws["gate"], ws["up"], ws["down"],
        kc, vc, jnp.asarray(pos, dtype=jnp.int32),
        CFG["heads"], CFG["kv_heads"],
    )


class TestBlockForward:
    def test_shapes(self):
        ws = make_weights()
        b, d = 2, CFG["d"]
        kv = CFG["kv_heads"] * (d // CFG["heads"])
        x = jnp.ones((b, d))
        kc = jnp.zeros((b, CFG["max_seq"], kv))
        vc = jnp.zeros((b, CFG["max_seq"], kv))
        xo, kco, vco = run_block(x, ws, kc, vc, 0)
        assert xo.shape == (b, d)
        assert kco.shape == kc.shape
        assert vco.shape == vc.shape

    def test_cache_written_at_pos(self):
        ws = make_weights()
        b, d = 1, CFG["d"]
        kv = CFG["kv_heads"] * (d // CFG["heads"])
        x = jnp.ones((b, d))
        kc = jnp.zeros((b, CFG["max_seq"], kv))
        vc = jnp.zeros((b, CFG["max_seq"], kv))
        _, kco, _ = run_block(x, ws, kc, vc, 3)
        assert float(jnp.abs(kco[0, 3]).sum()) > 0
        assert float(jnp.abs(kco[0, 4:]).sum()) == 0
        assert float(jnp.abs(kco[0, :3]).sum()) == 0

    def test_future_positions_masked(self):
        # Garbage in cache positions > pos must not change the output.
        ws = make_weights()
        b, d = 1, CFG["d"]
        kv = CFG["kv_heads"] * (d // CFG["heads"])
        x = jnp.ones((b, d))
        clean = jnp.zeros((b, CFG["max_seq"], kv))
        dirty = clean.at[:, 5:].set(1e6)
        xo1, _, _ = run_block(x, ws, clean, clean, 2)
        xo2, _, _ = run_block(x, ws, dirty, dirty, 2)
        np.testing.assert_allclose(np.asarray(xo1), np.asarray(xo2))

    def test_deterministic(self):
        ws = make_weights()
        b, d = 2, CFG["d"]
        kv = CFG["kv_heads"] * (d // CFG["heads"])
        x = jnp.ones((b, d)) * 0.3
        kc = jnp.zeros((b, CFG["max_seq"], kv))
        a = run_block(x, ws, kc, kc, 0)[0]
        bb = run_block(x, ws, kc, kc, 0)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


class TestComponents:
    def test_rmsnorm_unit_rms(self):
        x = jnp.asarray([[3.0, 4.0, 0.0, 0.0]])
        y = model.rmsnorm(x)
        ms = float(jnp.mean(y * y))
        assert abs(ms - 1.0) < 1e-4

    def test_rope_identity_at_zero(self):
        x = jnp.arange(1.0, 9.0)[None, :]
        y = model.rope(x, 1, 8, jnp.asarray(0))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)

    def test_rope_norm_preserving(self):
        x = jnp.arange(1.0, 9.0)[None, :]
        y = model.rope(x, 1, 8, jnp.asarray(5))
        assert abs(float(jnp.linalg.norm(y)) - float(jnp.linalg.norm(x))) < 1e-4

    def test_embed_gathers(self):
        emb = jnp.arange(12.0).reshape(4, 3)
        out = model.embed(jnp.asarray([2, 0]), emb)
        np.testing.assert_array_equal(np.asarray(out), [[6, 7, 8], [0, 1, 2]])

    def test_lm_head_shape(self):
        ws = make_weights()
        out = model.lm_head(jnp.ones((3, CFG["d"])), ws["head"])
        assert out.shape == (3, CFG["vocab"])


class TestDecodeStep:
    def test_full_step_greedy_changes_with_token(self):
        ws = make_weights()
        d, kv = CFG["d"], CFG["kv_heads"] * (CFG["d"] // CFG["heads"])
        params = {
            "embed.tok": ws["emb"], "lm_head": ws["head"],
            "n_heads": CFG["heads"], "n_kv_heads": CFG["kv_heads"],
        }
        for l in range(2):
            for nm, key in [("q_proj", "q"), ("k_proj", "k"), ("v_proj", "v"),
                            ("o_proj", "o"), ("gate_proj", "gate"),
                            ("up_proj", "up"), ("down_proj", "down")]:
                params[f"block.{l}.{nm}"] = ws[key]
        kcs = [jnp.zeros((1, CFG["max_seq"], kv)) for _ in range(2)]
        vcs = [jnp.zeros((1, CFG["max_seq"], kv)) for _ in range(2)]
        l1, _, _ = model.decode_step(params, jnp.asarray([3]), kcs, vcs, jnp.asarray(0))
        l2, _, _ = model.decode_step(params, jnp.asarray([9]), kcs, vcs, jnp.asarray(0))
        assert l1.shape == (1, CFG["vocab"])
        assert not np.array_equal(np.asarray(l1), np.asarray(l2))


class TestLowering:
    def test_block_lowers_to_hlo_text(self):
        # The aot.py path in miniature: block_forward -> stablehlo -> HLO text.
        from compile.aot import to_hlo_text, spec
        d, ff, ms = 16, 32, 8
        kv = 8

        def fn(x, q, k, v, o, g, u, dn, kc, vc, pos):
            return model.block_forward(x, q, k, v, o, g, u, dn, kc, vc, pos, 2, 1)

        lowered = jax.jit(fn).lower(
            spec((1, d)), spec((d, d)), spec((d, kv)), spec((d, kv)), spec((d, d)),
            spec((d, ff)), spec((d, ff)), spec((ff, d)),
            spec((1, ms, kv)), spec((1, ms, kv)), spec((), jnp.int32),
        )
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert len(text) > 1000
