"""AOT lowering smoke tests: every artifact kind lowers to valid HLO
text with the shapes meta.json promises."""

import json
import os

import pytest

from compile import aot


class TestLowering:
    def test_embed_lowers(self):
        text = aot.lower_embed(aot.TINY_100M, 2)
        assert text.startswith("HloModule") or "HloModule" in text
        # Shape appears in the HLO signature.
        assert "s32[2]" in text
        assert f"f32[{aot.TINY_100M['vocab_size']},{aot.TINY_100M['d_model']}]" in text

    def test_lm_head_lowers(self):
        text = aot.lower_lm_head(aot.TINY_100M, 1)
        assert "HloModule" in text
        assert f"f32[1,{aot.TINY_100M['d_model']}]" in text

    def test_block_fwd_lowers_with_cache_shapes(self):
        cfg = dict(aot.TINY_100M)
        # Shrink for speed; structure is identical.
        cfg.update(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, max_seq_len=32)
        text = aot.lower_block_fwd(cfg, 2)
        assert "HloModule" in text
        kv = cfg["n_kv_heads"] * (cfg["d_model"] // cfg["n_heads"])
        assert f"f32[2,{cfg['max_seq_len']},{kv}]" in text

    def test_meta_json_matches_artifacts(self):
        # Only meaningful after `make artifacts`.
        out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        meta_path = os.path.join(out, "meta.json")
        if not os.path.exists(meta_path):
            pytest.skip("artifacts not built")
        meta = json.load(open(meta_path))
        assert meta["model"]["d_model"] == aot.TINY_100M["d_model"]
        for name, fname in meta["artifacts"].items():
            assert os.path.exists(os.path.join(out, fname)), name
        if "df11_decode" in meta:
            for f in ["demo_encoded.bin", "demo_expected.bin", "demo_luts.bin"]:
                assert os.path.exists(os.path.join(out, f))
